"""Fault injection and self-healing transfers: the seeded fault harness
itself (determinism, kill/errno raising, suppression), resumable edges
(kill the importer mid-transfer on every transport, assert bit-identical
recovery with exactly one retry and a re-send bounded by the acked
watermark), transient-errno retry, the shm->socket failover ladder,
corruption recovery via full re-run, doorbell-degrade (broken doorbells
fall back to polling, transfer still completes), leased directory
registrations (expiry GC, renewal liveness), and the crash sweep that
unlinks orphaned ring segments *and* their doorbell fifos.

Seeded via ``PIPEGEN_FAULT_SEED`` so CI can run the same scenarios under
several fixed seeds (the chaos leg); every assertion is seed-independent
— the seed only perturbs rule evaluation order and jitter.
"""

import errno
import multiprocessing
import os
import signal
import threading
import time

import pytest

from repro.core import faults
from repro.core.datapipe import DataPipeInput, PipeConfig
from repro.core.directory import Endpoint, WorkerDirectory, set_directory
from repro.core.faults import FaultPlan, InjectedPeerDeath
from repro.core.plan import plan
from repro.core.shm_ring import (
    ShmRing,
    _db_path,
    doorbell_supported,
    sweep_orphans,
)
from repro.core.transport import Channel
from repro.engines import make_engine, make_paper_block
from repro.engines.base import assert_blocks_equal

SEED = int(os.environ.get("PIPEGEN_FAULT_SEED", "42"))

needs_doorbell = pytest.mark.skipif(
    not doorbell_supported(), reason="platform has no eventfd/fifo doorbell")

_mp = multiprocessing.get_context("spawn")
JOIN_S = 60

N_ROWS = 640
BLOCK_ROWS = 64  # -> 10 data frames per transfer
N_BLOCKS = N_ROWS // BLOCK_ROWS


def _edge_cfg(transport: str) -> PipeConfig:
    return PipeConfig(mode="arrowcol", block_rows=BLOCK_ROWS,
                      transport=transport)


def _one_edge(src, dst, transport: str, **options):
    set_directory(WorkerDirectory())
    return (plan(negotiate=False)
            .move(src, "t", dst, "t2", config=_edge_cfg(transport),
                  timeout=30)
            .options(**options)
            .compile()
            .execute(raise_on_error=False))


def _engines(seed: int = 7):
    src, dst = make_engine("colstore"), make_engine("colstore")
    block = make_paper_block(N_ROWS, seed=seed)
    src.put_block("t", block)
    return src, dst, block


# -- the harness itself -------------------------------------------------------------


def test_fault_plan_is_deterministic_per_seed():
    def run(seed):
        p = (FaultPlan(seed)
             .drop("transport.send", count=-1, prob=0.3)
             .duplicate("transport.send", count=-1, prob=0.1))
        out = []
        with faults.use(p):
            for _ in range(60):
                out.append(faults.fire("transport.send",
                                       transport="socket", kind=b"B"))
        return out, len(p.events)

    a, na = run(SEED)
    b, nb = run(SEED)
    assert a == b and na == nb  # same seed, same event order -> same fires
    assert 0 < na < 60  # probabilistic rules actually both fired and passed


def test_fire_raises_kill_and_errno_and_respects_suppression():
    p = (FaultPlan(SEED)
         .kill("transport.recv", at=1)
         .fail_errno("transport.send", errno.ECONNRESET, at=1))
    with faults.use(p):
        with faults.suppressed():  # masked: rules must not consume events
            assert faults.fire("transport.recv", transport="socket") is None
        with pytest.raises(InjectedPeerDeath) as death:
            faults.fire("transport.recv", transport="socket")
        assert isinstance(death.value, BrokenPipeError)  # the pipe contract
        with pytest.raises(OSError) as oe:
            faults.fire("transport.send", transport="socket", kind=b"S")
        assert oe.value.errno == errno.ECONNRESET
    assert p.fired("transport.recv") == 1 and p.fired() == 2


def test_rules_fire_on_nth_matching_event_only():
    p = FaultPlan(SEED).drop("transport.send", at=3, kind=b"B")
    with faults.use(p):
        # non-matching kinds do not advance the rule's event counter
        assert faults.fire("transport.send", transport="socket",
                           kind=b"S") is None
        for want in (None, None, "drop", None):
            got = faults.fire("transport.send", transport="socket",
                              kind=b"B")
            assert got == want


def test_drop_rpc_eats_a_directory_operation():
    d = WorkerDirectory()
    with faults.use(FaultPlan(SEED).drop_rpc("register")):
        with pytest.raises(ConnectionResetError):
            d.register("ds", Endpoint(channel=Channel()), "q0")
        d.register("ds", Endpoint(channel=Channel()), "q0")  # rule spent
    assert d.query("ds", "q0", timeout=1.0).is_channel


# -- resumable edges: kill the importer mid-transfer --------------------------------


@pytest.mark.parametrize("transport", ["socket", "channel", "shm"])
def test_kill_importer_midstream_resumes_bit_identical(transport):
    """The acceptance scenario: the importer dies on its 5th frame recv
    (schema, RESUME hello, two data blocks acked), the retry re-opens the
    edge, the exporter skips exactly the acked watermark, and the result
    is bit-identical — with exactly one retry."""
    src, dst, block = _engines()
    fp = FaultPlan(SEED).kill("transport.recv", at=5, count=1)
    with faults.use(fp):
        res = _one_edge(src, dst, transport, retries=1, failover=False)
    assert not res.exceptions, res.errors
    r = res.single()
    assert_blocks_equal(dst.get_block("t2"), block,
                        check_names=False)  # bit-identical data
    assert len(r.attempts) == 2  # exactly one retry
    assert r.attempts[0]["ok"] is False and r.attempts[1]["ok"] is True
    assert r.attempts[1]["transport"] == transport  # failover disabled
    assert r.errors and r.errors[0].startswith("attempt 0")
    # the re-send is bounded by the watermark gap: the importer acked two
    # data frames before dying, so the retry replays those locally and
    # the exporter ships only the remaining 8 (+ schema, hello, EOF)
    watermark = r.import_stats.resume_replayed
    assert watermark == 2
    assert r.export_stats.resume_skipped == watermark
    assert r.export_stats.frames_sent == (N_BLOCKS - watermark) + 3
    assert r.rows == N_ROWS


def test_transient_send_errno_is_retried_with_resume():
    """A transient sendmsg failure (ECONNRESET on the 4th frame = the 2nd
    data block) costs one retry; the first block was already acked, so the
    retry moves only the tail."""
    src, dst, block = _engines(seed=11)
    fp = FaultPlan(SEED).fail_errno("transport.send", errno.ECONNRESET,
                                    at=4, count=1)
    with faults.use(fp):
        res = _one_edge(src, dst, "socket", retries=2, failover=False)
    assert not res.exceptions, res.errors
    r = res.single()
    assert_blocks_equal(dst.get_block("t2"), block, check_names=False)
    assert len(r.attempts) == 2
    assert r.import_stats.resume_replayed == 1
    assert r.export_stats.resume_skipped == 1


def test_failover_ladder_shm_to_socket():
    """A transport-level fault on a shm edge retries over the socket
    rendezvous instead (the colocation assumption may itself be what
    broke); the attempt history records the ladder step."""
    src, dst, block = _engines(seed=5)
    fp = FaultPlan(SEED).fail_errno("transport.send", errno.EIO, at=3,
                                    count=1, transport="shm")
    with faults.use(fp):
        res = _one_edge(src, dst, "shm", retries=1)  # failover defaults on
    assert not res.exceptions, res.errors
    r = res.single()
    assert_blocks_equal(dst.get_block("t2"), block, check_names=False)
    assert [a["transport"] for a in r.attempts] == ["shm", "socket"]
    assert any("failover: shm -> socket" in e for e in r.errors)


def test_corrupt_schema_frame_recovers_via_full_rerun():
    """Corruption is the one failure resume must NOT heal: the poisoned
    frame is already staged in the importer's ledger, so the edge opts out
    of resume and the retry re-runs from frame 0."""
    src, dst, block = _engines(seed=3)
    fp = FaultPlan(SEED).corrupt("transport.send", at=1, count=1)
    with faults.use(fp):
        res = _one_edge(src, dst, "socket", retries=1, resume=False,
                        failover=False)
    assert not res.exceptions, res.errors
    r = res.single()
    assert_blocks_equal(dst.get_block("t2"), block, check_names=False)
    assert len(r.attempts) == 2
    # full re-run: nothing replayed, nothing skipped, all frames re-sent
    assert r.import_stats.resume_replayed == 0
    assert r.export_stats.resume_skipped == 0
    assert r.export_stats.frames_sent == N_BLOCKS + 2  # S + blocks + EOF


def test_retry_budget_deadline_caps_attempts():
    """An edge that keeps dying stops retrying once the deadline budget
    is spent, and says so in the error history."""
    src, dst, _ = _engines(seed=9)
    fp = FaultPlan(SEED).kill("transport.recv", count=-1)  # every recv dies
    t0 = time.monotonic()
    with faults.use(fp):
        res = _one_edge(src, dst, "socket", retries=50, backoff=0.2,
                        deadline=0.5, failover=False)
    assert res.exceptions  # genuinely unrecoverable
    r = res.single()
    assert 1 <= len(r.attempts) < 51
    assert any("retry budget exhausted" in e for e in r.errors)
    assert time.monotonic() - t0 < 10.0


# -- doorbell degrade (satellite: broken doorbell -> polling, not a hang) -----------


@needs_doorbell
def test_broken_doorbell_degrades_to_polling_and_completes():
    src, dst, block = _engines(seed=13)
    fp = (FaultPlan(SEED)
          .break_doorbell()
          # hold the first frame back long enough that the importer's
          # wait outlives the spin window and must poll-sleep
          .delay("transport.send", 0.05, at=1, count=1))
    with faults.use(fp):
        res = _one_edge(src, dst, "shm")
    assert not res.exceptions, res.errors
    r = res.single()
    assert_blocks_equal(dst.get_block("t2"), block, check_names=False)
    assert fp.fired("shm.doorbell.open") > 0  # the break actually happened
    total_polls = (r.import_stats.poll_sleeps + r.export_stats.poll_sleeps)
    assert total_polls > 0  # degraded to the capped-poll path, not a hang


# -- flight recorder: terminal failures arrive with a timeline ----------------------


def test_terminal_failure_carries_flight_timeline(tmp_path, monkeypatch):
    """An unrecoverable seeded fault must surface with the edge's flight
    recorder stapled on: the raised error names the injected fault and
    the attempts, and the chaos CI leg's PIPEGEN_FLIGHT_DUMP file gets a
    copy it can assert on."""
    from repro.core import telemetry

    dump = tmp_path / "flight.txt"
    monkeypatch.setenv("PIPEGEN_FLIGHT_DUMP", str(dump))
    src, dst, _ = _engines(seed=21)
    fp = FaultPlan(SEED).kill("transport.recv", count=-1)  # every recv dies
    with faults.use(fp):
        res = _one_edge(src, dst, "socket", retries=1, backoff=0.01,
                        failover=False)
    assert res.exceptions
    e = res.exceptions[0]
    timeline = getattr(e, "flight_timeline", None)
    assert timeline is not None  # the error carries its causal history
    assert "edge.attempt" in timeline
    assert "fault.injected" in timeline  # the seeded kill shows up
    assert "edge.attempt" in str(e)  # visible in a bare traceback too
    assert dump.exists() and "fault.injected" in dump.read_text()
    assert len(telemetry.fault_recorder) > 0


# -- leased registrations -----------------------------------------------------------


def test_lease_expiry_gc_drops_unrenewed_registration():
    d = WorkerDirectory(lease_ttl=0.15)
    d.register("stale", Endpoint(channel=Channel()), "q0")
    time.sleep(0.3)
    with pytest.raises(TimeoutError):
        d.query("stale", "q0", timeout=0.05)
    assert d.renew("stale", "q0") == 0  # too late: caller must re-register


def test_importer_lease_renewal_keeps_slow_rendezvous_alive():
    """A DataPipeInput opened with ``lease_s`` renews its own registration
    in the background: an exporter that shows up only after several TTLs
    still finds the endpoint (liveness by heartbeat, not luck)."""
    d = WorkerDirectory(lease_ttl=0.2)
    set_directory(d)
    pipe = DataPipeInput("db://leased?workers=1&query=L1",
                         transport="channel", lease_s=0.2)
    try:
        time.sleep(0.65)  # > 3 lease TTLs
        ep = d.query("leased", "L1", timeout=0.5)
        assert ep.is_channel
        assert ep.lease_deadline > 0  # the entry really was leased
    finally:
        pipe.close()


# -- crash sweep: orphaned segments AND their doorbell fifos ------------------------


def _child_create_ring_and_die(name):
    from multiprocessing import resource_tracker

    ring = ShmRing.create(capacity=8192, name=name, role="reader")
    try:  # simulate a true crash leak: nobody tracks the segment
        resource_tracker.unregister(ring.shm._name, "shared_memory")
    except Exception:
        pass
    os.kill(os.getpid(), signal.SIGKILL)


@needs_doorbell
def test_directory_sweep_unlinks_orphan_segment_and_fifos():
    name = f"pgring-sweeptest{os.getpid():x}"
    fifos = [_db_path(name, "w"), _db_path(name, "r0")]
    p = _mp.Process(target=_child_create_ring_and_die, args=(name,))
    p.start()
    p.join(JOIN_S)
    assert not p.is_alive()
    assert os.path.exists(f"/dev/shm/{name}")  # the leak is real
    assert all(os.path.exists(f) for f in fifos)
    swept = WorkerDirectory().sweep(orphan_min_age_s=0.0)
    assert name in swept
    assert not os.path.exists(f"/dev/shm/{name}")
    assert not any(os.path.exists(f) for f in fifos)  # fifos swept too


@needs_doorbell
def test_sweep_removes_fifos_whose_segment_is_already_gone():
    # a process can die between fifo creation and segment registration —
    # or a foreign cleaner can take the segment first; either way the
    # fifos must not outlive it
    name = f"pgring-fifoonly{os.getpid():x}"
    fifos = [_db_path(name, "w"), _db_path(name, "r0")]
    for f in fifos:
        os.mkfifo(f)
    try:
        swept = sweep_orphans(min_age_s=0.0)
        assert all(os.path.basename(f) in swept for f in fifos)
        assert not any(os.path.exists(f) for f in fifos)
    finally:
        for f in fifos:
            try:
                os.unlink(f)
            except FileNotFoundError:
                pass


# -- continuous pipes: exporter crash + watermark resume ----------------------------


def _crash_publisher_child(port, name, start_epoch, n_epochs, ready):
    from repro.core.directory import DirectoryClient
    from repro.core.subscribe import Publication

    client = DirectoryClient("127.0.0.1", port)
    schema = make_paper_block(1).schema
    pub = Publication(name, schema, directory=client,
                      start_epoch=start_epoch)
    for e in range(start_epoch + 1, start_epoch + n_epochs + 1):
        pub.commit([make_paper_block(BLOCK_ROWS, seed=1000 + e)])
    ready.set()
    time.sleep(JOIN_S)  # parked: the parent SIGKILLs (crash) or reaps us


def test_dead_requester_query_does_not_eat_registration():
    """The endpoint-pop handoff must survive a requester that dies between
    asking and hearing the answer.  A SIGKILLed publisher leaves exactly
    such a query parked in a directory handler; when a new subscriber
    registers, that dead query pops the endpoint and writes the response
    into a void.  Without the ack/restitution handshake the registration
    is consumed forever and the live subscriber starves."""
    import json as _json
    import socket

    from repro.core.directory import DirectoryClient, DirectoryServer

    server = DirectoryServer().start()
    try:
        # park a query server-side, then "die" without reading the answer
        s = socket.create_connection(("127.0.0.1", server.port), timeout=10)
        s.sendall(_json.dumps(
            {"op": "query", "dataset": "ds", "query_id": "q",
             "timeout": 30.0}).encode() + b"\n")
        time.sleep(0.3)  # let the handler park in the rendezvous wait
        s.close()

        client = DirectoryClient("127.0.0.1", server.port)
        client.register("ds", Endpoint(host="127.0.0.1", port=12345), "q")
        # the dead query races us to the pop; whether it wins or not, the
        # endpoint must end up with the live query below
        ep = client.query("ds", "q", timeout=JOIN_S)
        assert ep.port == 12345
    finally:
        server.stop()


def test_publisher_sigkill_watermark_resume_over_socket():
    """Exporter crash + restart heals via re-publish + resubscribe: a
    publisher process is SIGKILLed mid-stream; its successor re-publishes
    the same name starting at the crashed head, and the subscriber —
    resubscribing at its watermark — receives exactly the missing epochs
    as replayed deltas (no snapshot, no duplicates), folding to the full
    relation bit-identically."""
    from repro.core.directory import DirectoryClient, DirectoryServer
    from repro.core.subscribe import Subscription
    from repro.core.types import ColumnBlock

    server = DirectoryServer().start()
    name = f"crash.pub{os.getpid():x}"
    p1 = p2 = None
    try:
        ready1 = _mp.Event()
        p1 = _mp.Process(target=_crash_publisher_child,
                         args=(server.port, name, 0, 3, ready1))
        p1.start()
        assert ready1.wait(JOIN_S)
        client = DirectoryClient("127.0.0.1", server.port)
        sub = Subscription(name, watermark=0, directory=client,
                           transport="socket", timeout=JOIN_S)
        got = []
        deadline = time.monotonic() + JOIN_S
        while len(got) < 3 and time.monotonic() < deadline:
            got.extend(sub.poll(timeout=0.2))
        assert [e.epoch for e in got] == [1, 2, 3]
        p1.kill()  # SIGKILL: no EOF courtesy, no unpublish, no lease release
        p1.join(JOIN_S)
        with pytest.raises(BrokenPipeError):
            deadline = time.monotonic() + JOIN_S
            while time.monotonic() < deadline:
                got.extend(sub.poll(timeout=0.2))
        wm = sub.watermark
        assert wm == 3  # the watermark survives the wreck
        sub.close()

        ready2 = _mp.Event()
        p2 = _mp.Process(target=_crash_publisher_child,
                         args=(server.port, name, wm, 2, ready2))
        p2.start()
        assert ready2.wait(JOIN_S)
        sub2 = Subscription(name, watermark=wm, directory=client,
                            transport="socket", timeout=JOIN_S)
        try:
            more = []
            deadline = time.monotonic() + JOIN_S
            while len(more) < 2 and time.monotonic() < deadline:
                more.extend(sub2.poll(timeout=0.2))
            assert [e.epoch for e in more] == [4, 5]
            assert all(e.kind == "delta" for e in more)  # replay, no snapshot
        finally:
            sub2.close()
        folded = ColumnBlock.concat([e.block for e in got + more])
        expect = ColumnBlock.concat(
            [make_paper_block(BLOCK_ROWS, seed=1000 + e)
             for e in range(1, 6)])
        assert_blocks_equal(folded, expect)
    finally:
        for p in (p1, p2):
            if p is not None and p.is_alive():
                p.kill()
                p.join(JOIN_S)
        server.stop()
