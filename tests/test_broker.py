"""The pipe broker and the three scale bugs it rides on: poll-based
doorbell waits (fds >= 1024 crashed ``select.select``), the bounded
directory RPC handler pool (one untracked thread per connection before),
dead-lease heartbeats surfacing as loud importer failures that the
executor's retry path heals, and the broker itself — doorbell hub,
admission control, QoS priority, per-tenant quotas, and fd flatness
under hundreds of concurrent small plans."""

import json
import os
import socket
import tempfile
import threading
import time

import pytest

from repro.core.broker import (
    BrokerBusy,
    DoorbellHub,
    PipeBroker,
    TenantQuota,
    process_fd_count,
    set_broker,
)
from repro.core.datapipe import DataPipeInput, PipeConfig
from repro.core.directory import DirectoryServer, WorkerDirectory, set_directory
from repro.core.plan import PlanError, plan
from repro.core.shm_ring import _Doorbell, doorbell_supported
from repro.engines import make_engine, make_paper_block
from repro.engines.base import assert_blocks_equal

needs_doorbell = pytest.mark.skipif(
    not doorbell_supported(), reason="platform has no eventfd/fifo doorbell")


def _small_edge_cfg(transport="shm", **kw):
    return PipeConfig(mode="arrowcol", block_rows=32, transport=transport,
                      **kw)


def _one_transfer(src_rows=64, transport="shm", seed=3, **options):
    src, dst = make_engine("colstore"), make_engine("colstore")
    blk = make_paper_block(src_rows, seed=seed)
    src.put_block("t", blk)
    res = (plan(negotiate=False)
           .move(src, "t", dst, "t2",
                 config=_small_edge_cfg(transport), timeout=30)
           .options(**options)
           .compile()
           .execute())
    return blk, dst.get_block("t2"), res


# -- satellite 1: FD_SETSIZE ---------------------------------------------------------


@needs_doorbell
def test_doorbell_wait_survives_fd_over_1024():
    """select.select raised ValueError for any fd >= FD_SETSIZE; the
    poll-based wait must not care where dup2 lands the fd."""
    import resource

    soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
    if hard != resource.RLIM_INFINITY and hard < 1600:
        pytest.skip(f"hard RLIMIT_NOFILE {hard} < 1600")
    if soft != resource.RLIM_INFINITY and soft < 1600:
        resource.setrlimit(resource.RLIMIT_NOFILE, (1600, hard))
    path = os.path.join(tempfile.gettempdir(),
                        f"pgtest-{os.getpid()}.pgdb-hi")
    os.mkfifo(path)
    db = None
    try:
        db = _Doorbell(path, create_event=False)
        target = 1500
        os.dup2(db.fd, target)
        os.close(db.fd)
        db.fd = target
        assert db.fd >= 1024
        # empty: a select.select-based wait would raise ValueError here
        assert db.wait(0.05) is False
        wfd = os.open(path, os.O_WRONLY | os.O_NONBLOCK)
        try:
            os.write(wfd, b"!")
        finally:
            os.close(wfd)
        assert db.wait(1.0) is True
    finally:
        if db is not None:
            db.close()
        os.unlink(path)


@needs_doorbell
def test_hub_wait_delivers_wakeup():
    """A hub-mediated wait parks on an Event and is woken by the hub's
    selector thread draining the fifo."""
    path = os.path.join(tempfile.gettempdir(),
                        f"pgtest-{os.getpid()}.pgdb-hub")
    os.mkfifo(path)
    hub = DoorbellHub().start()
    db = None
    try:
        db = _Doorbell(path, create_event=False)
        woke = []
        t = threading.Thread(target=lambda: woke.append(hub.wait(db, 5.0)))
        t.start()
        time.sleep(0.1)  # let the waiter register + park
        wfd = os.open(path, os.O_WRONLY | os.O_NONBLOCK)
        try:
            os.write(wfd, b"!")
        finally:
            os.close(wfd)
        t.join(timeout=5.0)
        assert woke == [True]
        assert hub.wakeups >= 1 and hub.registered == 1
        hub.discard(db)
        assert hub.registered == 0
    finally:
        if db is not None:
            db.close()
        hub.stop()
        os.unlink(path)


# -- satellite 2: bounded RPC handlers ----------------------------------------------


def _rpc(host, port, req, timeout=10.0):
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall(json.dumps(req).encode() + b"\n")
        f = s.makefile("rb")
        return json.loads(f.readline())


def test_directory_server_thread_count_is_bounded():
    srv = DirectoryServer("127.0.0.1", 0, handlers=4).start()
    try:
        baseline = threading.active_count()
        peak = [baseline]
        stop = threading.Event()

        def sample():
            while not stop.is_set():
                peak[0] = max(peak[0], threading.active_count())
                time.sleep(0.002)

        sampler = threading.Thread(target=sample)
        sampler.start()
        n_clients, per_client = 16, 8

        def client():
            for _ in range(per_client):
                r = _rpc(srv.host, srv.port,
                         {"op": "renew", "dataset": "none",
                          "query_id": "q", "pid": 1})
                assert r["ok"]

        clients = [threading.Thread(target=client) for _ in range(n_clients)]
        for c in clients:
            c.start()
        for c in clients:
            c.join(timeout=30.0)
        stop.set()
        sampler.join(timeout=5.0)
        # the burst adds client + sampler threads only: the server must
        # not have grown beyond its fixed pool (the old code added one
        # daemon thread per connection — 128 here)
        assert peak[0] <= baseline + n_clients + 2
    finally:
        srv.stop()
    # stop() joins everything it started
    names = [t.name for t in threading.enumerate()]
    assert not any(n.startswith("pgdir-handler-") for n in names)


def test_directory_server_blocking_query_does_not_starve_fast_ops():
    """A pool-full pile of blocked queries must not delay the register
    they are all waiting for (the fast lane runs in the accept loop)."""
    srv = DirectoryServer("127.0.0.1", 0, handlers=2).start()
    try:
        results = []

        def q():
            results.append(_rpc(
                srv.host, srv.port,
                {"op": "query", "dataset": "d", "query_id": "q1",
                 "timeout": 10.0}, timeout=30.0))

        qs = [threading.Thread(target=q) for _ in range(2)]  # fill the pool
        for t in qs:
            t.start()
        time.sleep(0.2)
        r = _rpc(srv.host, srv.port, {
            "op": "register", "dataset": "d", "query_id": "q1",
            "host": "127.0.0.1", "port": 5, "pid": 1, "workers": 1,
            "transport": "socket"})
        assert r["ok"]
        for t in qs:
            t.join(timeout=30.0)
        assert len(results) == 2
        assert any(x["ok"] for x in results)  # one query got the endpoint
    finally:
        srv.stop()


# -- satellite 3: dead-lease heartbeats ---------------------------------------------


class _LeaseKiller(WorkerDirectory):
    """Simulates the GC'd-registration race: the first attempt's renewals
    find nothing (entry dropped, renew -> 0); retry attempts (query ids
    carrying the executor's ``a<k>`` suffix) behave normally."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.killed = threading.Event()

    def _is_retry(self, query_id):
        return "a" in str(query_id)

    def renew(self, dataset, query_id="0", pid=None, lease_s=None):
        if not self._is_retry(query_id):
            with self._lock:  # the GC: registration dropped, shm released
                st = self._queries.get((dataset, str(query_id)))
                if st is not None:
                    st.entries.clear()
                self._lock.notify_all()
            self.killed.set()
            return 0
        return super().renew(dataset, query_id, pid=pid, lease_s=lease_s)

    def query(self, dataset, query_id="0", export_workers=None,
              timeout=30.0):
        if not self._is_retry(query_id):
            # exporter arrives "late": after the lease is already gone
            self.killed.wait(timeout=5.0)
        return super().query(dataset, query_id, export_workers,
                             timeout=timeout)


@needs_doorbell
def test_renew_zero_surfaces_as_loud_importer_failure():
    d = _LeaseKiller()
    set_directory(d)
    inp = DataPipeInput("db://dead?workers=1&query=q0", transport="shm",
                        lease_s=0.15)
    try:
        with pytest.raises(BrokenPipeError) as e:
            inp.read(1)  # parked on the ring until the renew loop aborts it
        assert "lease" in str(e.value)
        assert d.killed.is_set()
    finally:
        inp.close()


@needs_doorbell
def test_lease_loss_heals_through_executor_retry():
    d = _LeaseKiller()
    set_directory(d)
    src, dst = make_engine("colstore"), make_engine("colstore")
    blk = make_paper_block(96, seed=11)
    src.put_block("t", blk)
    res = (plan(negotiate=False)
           .move(src, "t", dst, "t2",
                 config=_small_edge_cfg("shm", lease_s=0.15),
                 timeout=1.5, retries=1, backoff=0.01)
           .compile()
           .execute())
    r = res.single()
    assert len(r.attempts) == 2  # attempt 0 lost its lease, attempt 1 ran
    assert not r.attempts[0]["ok"] and r.attempts[1]["ok"]
    assert_blocks_equal(blk, dst.get_block("t2"), check_names=False)
    # attempt 0's abandoned exporter must unwind within its (clamped)
    # connect timeout — a lingering thread still holds its open-splice
    for t in threading.enumerate():
        if t.name.startswith("pipegen-export"):
            t.join(timeout=10.0)
            assert not t.is_alive(), t.name


def test_renew_of_popped_endpoint_is_not_lease_loss():
    """Once the exporter pops the registration the importer's heartbeat
    must report success (the transfer is past rendezvous), not the
    fatal renewed-0."""
    from repro.core.directory import Endpoint

    d = WorkerDirectory(lease_ttl=30.0)
    ep = Endpoint(host="h", port=1, pid=os.getpid())
    d.register("ds", ep, "q1", lease_s=30.0)
    assert d.renew("ds", "q1", pid=os.getpid()) == 1
    got = d.query("ds", "q1", timeout=1.0)  # pops the entry
    assert got.pid == os.getpid()
    assert d.renew("ds", "q1", pid=os.getpid()) == 1  # popped, not GC'd
    assert d.renew("ds", "q1", pid=999999) == 0  # unknown pid: truly gone


# -- the broker: admission + QoS + quotas -------------------------------------------


def test_admission_blocks_until_release_and_rejects_never_fits():
    with PipeBroker(max_rings=2, hub=False) as b:
        a = b.admit(rings=2)
        with pytest.raises(BrokerBusy):
            b.admit(rings=1, timeout=0.2)
        with pytest.raises(BrokerBusy):  # can never fit: instant reject
            b.admit(rings=3, timeout=30.0)
        got = []
        t = threading.Thread(
            target=lambda: got.append(b.admit(rings=2, timeout=10.0)))
        t.start()
        time.sleep(0.1)
        assert b.stats()["waiting"] == 1
        a.release()
        t.join(timeout=10.0)
        assert len(got) == 1
        got[0].release()
        assert b.stats()["active_rings"] == 0
        assert b.rejected == 2 and b.queued >= 1


def test_latency_class_jumps_bulk_queue():
    with PipeBroker(max_rings=2, hub=False) as b:
        a = b.admit(rings=2, qos="bulk")
        order = []
        lock = threading.Lock()

        def take(qos):
            adm = b.admit(rings=2, qos=qos, timeout=10.0)
            with lock:
                order.append(qos)
            time.sleep(0.15)
            adm.release()

        bulk = threading.Thread(target=take, args=("bulk",))
        bulk.start()
        time.sleep(0.1)  # bulk queues first...
        lat = threading.Thread(target=take, args=("latency",))
        lat.start()
        time.sleep(0.1)
        assert b.stats()["waiting"] == 2
        a.release()  # ...but latency is admitted first
        bulk.join(timeout=10.0)
        lat.join(timeout=10.0)
        assert order == ["latency", "bulk"]


def test_oversized_ticket_does_not_starve_small_ones():
    with PipeBroker(max_rings=4, hub=False) as b:
        a = b.admit(rings=3)
        blocked = threading.Thread(
            target=lambda: b.admit(rings=4, timeout=3.0).release())
        blocked.start()
        time.sleep(0.1)
        small = b.admit(rings=1, timeout=0.5)  # fits NOW; big one waits
        small.release()
        a.release()
        blocked.join(timeout=10.0)


def test_tenant_quotas_isolate_budgets():
    with PipeBroker(max_rings=None, hub=False,
                    tenants={"a": TenantQuota(max_rings=1)}) as b:
        a1 = b.admit(tenant="a", rings=1)
        with pytest.raises(BrokerBusy):
            b.admit(tenant="a", rings=1, timeout=0.2)  # a is at quota
        b1 = b.admit(tenant="b", rings=8, timeout=0.2)  # b is not
        a1.release()
        a2 = b.admit(tenant="a", rings=1, timeout=1.0)
        a2.release()
        b1.release()


def test_qos_concurrency_cap():
    with PipeBroker(max_rings=None, hub=False,
                    qos_concurrency={"bulk": 1}) as b:
        x = b.admit(qos="bulk", rings=1)
        with pytest.raises(BrokerBusy):
            b.admit(qos="bulk", rings=1, timeout=0.2)
        y = b.admit(qos="latency", rings=1, timeout=0.2)  # uncapped class
        x.release()
        y.release()


def test_plan_validates_qos_and_broker_rejection_fails_edge():
    with pytest.raises(PlanError):
        src, dst = make_engine("colstore"), make_engine("colstore")
        plan().move(src, "t", dst, "t2", qos="turbo").compile()
    b = PipeBroker(max_rings=None, hub=False,
                   default_quota=TenantQuota(max_rings=0)).install()
    try:
        src, dst = make_engine("colstore"), make_engine("colstore")
        src.put_block("t", make_paper_block(64, seed=3))
        res = (plan(negotiate=False)
               .move(src, "t", dst, "t2",
                     config=_small_edge_cfg("shm"), timeout=5)
               .compile()
               .execute(raise_on_error=False))
        assert res.exceptions and isinstance(res.exceptions[0], BrokerBusy)
    finally:
        b.stop()
        set_broker(None)


# -- the broker: hub-mediated transfers + fd flatness -------------------------------


@needs_doorbell
def test_transfer_through_installed_broker_uses_hub():
    from repro.core.shm_ring import ShmRing, ShmRingTransport
    from repro.core.datapipe import FRAME_TEXT

    b = PipeBroker(max_rings=8).install()
    try:
        blk, got, _ = _one_transfer(src_rows=640, qos="latency")
        assert_blocks_equal(blk, got, check_names=False)
        st = b.stats()
        assert st["admitted"] == 1
        assert st["hub_registered"] == 0  # parked rings released their fds
        # a guaranteed-idle wait (slow writer) must park through the hub
        ring = ShmRing.create(capacity=4096, role="reader")
        tx, rx = ShmRingTransport(ring), ShmRingTransport(ring)

        def send():
            time.sleep(0.1)  # reader reaches the parked doorbell wait
            tx.send_frames(FRAME_TEXT, [b"ping"])

        th = threading.Thread(target=send, daemon=True)
        th.start()
        assert rx.recv_frame() == (FRAME_TEXT, b"ping")
        th.join(10.0)
        ring.close()
        assert b.stats()["hub_wakeups"] >= 1
    finally:
        b.stop()


@needs_doorbell
def test_broker_sustains_200_concurrent_plans_with_flat_fds():
    """The acceptance bar: >= 200 concurrent small plans through ONE
    broker, fd count bounded by admission (not by plan count)."""
    n_plans = 200
    b = PipeBroker(max_rings=16, admit_timeout=120.0).install()
    try:
        _one_transfer(src_rows=32)  # warm the adapter cache serially
        base = process_fd_count()
        peak = [base]
        stop = threading.Event()

        def sample():
            while not stop.is_set():
                peak[0] = max(peak[0], process_fd_count())
                time.sleep(0.005)

        sampler = threading.Thread(target=sample)
        sampler.start()
        failures = []

        def one(i):
            try:
                blk, got, _ = _one_transfer(src_rows=32, seed=i)
                assert_blocks_equal(blk, got, check_names=False)
            except Exception as e:  # noqa: BLE001 - aggregated below
                failures.append((i, repr(e)))

        threads = [threading.Thread(target=one, args=(i,))
                   for i in range(n_plans)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300.0)
        stop.set()
        sampler.join(timeout=5.0)
        assert not failures, failures[:5]
        st = b.stats()
        assert st["admitted"] == n_plans + 1
        # flat: bounded by the 16-ring admission ceiling (each live SPSC
        # ring holds <= 6 doorbell fds across both in-process sides),
        # NOT by the 200 plans
        assert peak[0] - base < 16 * 6 + 40, (base, peak[0])
    finally:
        b.stop()
    after = process_fd_count()
    assert after <= base + 4, (base, after)  # pools drained, hub closed
