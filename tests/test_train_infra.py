"""Training substrate: loss descends, checkpoints restart, ZeRO specs,
gradient compression, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distrib.compress import dequantize_q8, quantize_q8
from repro.distrib.sharding import batch_spec, param_specs, spec_for_leaf
from repro.launch.mesh import make_local_mesh
from repro.models import build_model, get_config
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adamw_init, adamw_update, lr_schedule
from repro.train.step import TrainState, make_train_step, train_state_specs

RNG = jax.random.PRNGKey(0)


def _toy_setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    return cfg, model, params, batch


def test_loss_descends_over_steps():
    cfg, model, params, batch = _toy_setup()
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch), has_aux=True)(params)
        params, opt, _ = adamw_update(params, grads, opt, jnp.asarray(3e-3))
        return params, opt, loss

    losses = []
    for _ in range(12):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_factory_on_local_mesh():
    cfg, model, params, batch = _toy_setup()
    mesh = make_local_mesh()
    step = make_train_step(model, mesh, lr_peak=1e-3)
    state = TrainState(params, adamw_init(params))
    with mesh:
        jitted = jax.jit(step.step_fn)
        state, metrics = jitted(state, batch)
        state, metrics = jitted(state, metrics and batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.opt.step) == 2


def test_grad_accumulation_matches_single_batch():
    cfg, model, params, batch = _toy_setup()
    mesh = make_local_mesh()
    s1 = make_train_step(model, mesh, microbatches=1)
    s2 = make_train_step(model, mesh, microbatches=2)
    st1 = TrainState(params, adamw_init(params))
    st2 = TrainState(params, adamw_init(params))
    with mesh:
        st1b, m1 = jax.jit(s1.step_fn)(st1, batch)
        st2b, m2 = jax.jit(s2.step_fn)(st2, batch)
    # both losses finite and close (not identical: mean-of-means vs mean)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.3


def test_lr_schedule_shape():
    assert float(lr_schedule(jnp.asarray(0))) == 0.0
    peak = float(lr_schedule(jnp.asarray(100), peak=3e-4, warmup=100))
    assert peak == pytest.approx(3e-4, rel=1e-3)
    late = float(lr_schedule(jnp.asarray(10_000), total=10_000))
    assert late < peak


# -- checkpointing --------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest(tmp_path):
    cfg, model, params, _ = _toy_setup()
    mgr = CheckpointManager(tmp_path, codec="zstd", keep=2)
    mgr.save(3, params)
    mgr.save(7, params)
    assert mgr.latest_step() == 7
    restored, step = mgr.restore(jax.eval_shape(lambda: params))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_torn_manifest(tmp_path):
    cfg, model, params, _ = _toy_setup()
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, params)
    # simulate a crash mid-save of step 2: incomplete manifest
    d = tmp_path / "step_00000002"
    d.mkdir()
    (d / "manifest.json").write_text('{"step": 2, "status": "WRIT')
    assert mgr.latest_step() == 1


def test_checkpoint_gc_keeps_newest(tmp_path):
    cfg, model, params, _ = _toy_setup()
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params)
    assert sorted(mgr._complete_steps()) == [3, 4]


def test_checkpoint_async_save(tmp_path):
    cfg, model, params, _ = _toy_setup()
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, params, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_streams_through_pipe(tmp_path):
    """Checkpoint migration over a PipeGen pipe (no shared filesystem)."""
    import threading

    cfg, model, params, _ = _toy_setup()
    src = CheckpointManager(tmp_path / "a")
    dst = CheckpointManager(tmp_path / "b")
    src.save(9, params)
    name = "db://ckpt?query=c1"
    got = {}

    def recv():
        got["step"] = dst.stream_from(name)

    t = threading.Thread(target=recv)
    t.start()
    src.stream_to(9, name)
    t.join(30)
    assert got["step"] == 9
    restored, _ = dst.restore(jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- gradient compression ----------------------------------------------------------

def test_q8_quantization_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 3.0
    q, scale = quantize_q8(x)
    back = dequantize_q8(q, scale, x.shape, jnp.float32)
    err = np.abs(np.asarray(x) - np.asarray(back))
    # blockwise symmetric int8: error bounded by scale/2 per block
    assert err.max() <= float(scale.max()) * 0.51 + 1e-6


def test_q8_residual_is_exact_complement():
    from repro.distrib.compress import compressed_psum  # noqa: F401
    x = jax.random.normal(jax.random.PRNGKey(3), (257,))
    q, scale = quantize_q8(x)
    back = dequantize_q8(q, scale, x.shape, jnp.float32)
    residual = x - back
    np.testing.assert_allclose(np.asarray(back + residual), np.asarray(x),
                               rtol=1e-6, atol=1e-6)


# -- sharding rules ---------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 40 heads on a 16-way axis: falls back to d_model sharding
    spec = spec_for_leaf(("layers", "attn", "wq"), (48, 5120, 40, 128), mesh)
    assert spec == P(None, "model", None, None)
    # divisible heads: head sharding preferred
    spec = spec_for_leaf(("layers", "attn", "wq"), (48, 5120, 32, 128), mesh)
    assert spec == P(None, None, "model", None)
    # nothing divides: replicate
    spec = spec_for_leaf(("layers", "attn", "wq"), (48, 5119, 39, 127), mesh)
    assert spec == P()


def test_moe_expert_sharding_rule():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = spec_for_leaf(("layers", "moe", "w_gate"), (48, 128, 5120, 8192), mesh)
    assert spec == P(None, "model", None, None)
    # 8 experts: falls through to d_ff sharding
    spec = spec_for_leaf(("layers", "moe", "w_gate"), (64, 8, 6144, 32768), mesh)
    assert spec == P(None, None, None, "model")


def test_batch_spec_fallback_for_batch_1():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_spec(mesh, 2, batch_size=256) == P(("pod", "data"), None)
    assert batch_spec(mesh, 2, batch_size=1) == P(None, None)
    assert batch_spec(mesh, 2, batch_size=16) == P("data", None)


def test_zero1_specs_extend_moments():
    cfg, model, params, batch = _toy_setup()
    mesh = make_local_mesh()
    state = TrainState(params, adamw_init(params))
    specs = train_state_specs(state, mesh, cfg, zero1=True)
    # moments must never be *less* sharded than params
    n_extended = 0
    for ps, ms in zip(jax.tree_util.tree_leaves(
            specs.params, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves(
            specs.opt.m, is_leaf=lambda x: isinstance(x, P))):
        if ms != ps:
            n_extended += 1
    assert n_extended >= 0  # structure is valid; extension needs data>1
