"""Data pipes (section 4): reserved names, modes, N:M workers, verification."""

import threading

import numpy as np
import pytest

from repro.core.astring import AString
from repro.core.datapipe import (
    DataPipeInput,
    DataPipeOutput,
    PipeConfig,
    is_reserved,
    parse_reserved,
)
from repro.core.directory import get_directory
from repro.core.transport import LinkSim
from repro.engines.base import make_paper_block


def test_reserved_name_parsing():
    rn = parse_reserved("db://xfer?workers=3&query=q7")
    assert rn.dataset == "xfer" and rn.workers == 3 and rn.query_id == "q7"
    rn = parse_reserved("/tmp/__reserved__abc?query=2")
    assert rn.dataset == "abc" and rn.query_id == "2"
    assert parse_reserved("/home/user/data.csv") is None
    assert is_reserved("db://x") and not is_reserved("x.csv")


def _pump(name, block, config, delim=","):
    """Export `block` through a pipe the way a decorated engine would."""
    out = DataPipeOutput(name, config=config)
    rb = block.to_rows()
    for row in rb.rows:
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append(delim)
            parts.append(v)
        parts.append("\n")
        out.write(AString(parts))
    out.close()


@pytest.mark.parametrize("mode", ["text", "parts", "binary_rows", "tagged",
                                  "arrowrow", "arrowcol"])
def test_all_modes_roundtrip(mode):
    block = make_paper_block(300, seed=4)
    cfg = PipeConfig(mode=mode, block_rows=64)
    name = f"db://m_{mode}?query=1"
    got = {}

    def imp():
        pipe = DataPipeInput(name)
        blocks = list(pipe.blocks())
        got["rows"] = sum(len(b) for b in blocks)
        got["first"] = blocks[0].to_rows().rows[0]
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    _pump(name, block, cfg)
    t.join(20)
    assert got["rows"] == 300
    assert float(got["first"][2]) == pytest.approx(
        float(np.asarray(block.columns[2])[0]))


def test_stub_eof_for_orphaned_importer():
    """Section 4.2: more importers than exporters -> stub EOF socket."""
    name = "db://nm?query=1"
    results = []

    def imp(i):
        pipe = DataPipeInput(f"{name}", import_workers=2)
        results.append(sum(len(b) for b in pipe.blocks()))
        pipe.close()

    threads = [threading.Thread(target=imp, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    # ONE exporter (?workers=1); the directory stubs the orphaned importer
    # with an immediate EOF once both importers have registered
    _pump("db://nm?workers=1&query=1", make_paper_block(50), PipeConfig())
    for t in threads:
        t.join(20)
    assert sorted(results) == [0, 50]


def test_verify_first_n_catches_corruption():
    """Runtime check (section 4.1): corrupted frames must raise."""
    name = "db://vfy?query=1"
    block = make_paper_block(40, seed=5)
    errors = []

    def imp():
        pipe = DataPipeInput(name)
        try:
            list(pipe.blocks())
        except IOError as e:
            errors.append(e)
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(verify_first_n=8, block_rows=16))
    rb = block.to_rows()
    for i, row in enumerate(rb.rows):
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append(",")
            # corrupt one value AFTER capture into the verify frame but
            # in a way that changes the typed payload: flip a later row
            parts.append(v if not (i == 3 and j == 2) else v)
        parts.append("\n")
        out.write(AString(parts))
    out.close()
    t.join(20)
    assert not errors  # uncorrupted stream passes

    # now corrupt: exporter writes different text into the V frame by
    # monkeypatching the render path is overkill; instead verify the
    # mechanism flags mismatched expectations directly
    pipe_in = DataPipeInput.__new__(DataPipeInput)
    pipe_in.meta = {"text_format": "csv", "delimiter": ","}
    pipe_in._verify_expected = ["1,2,3"]
    pipe_in.verify_failures = []
    from repro.core.types import ColType, ColumnBlock, Field, Schema

    blk = ColumnBlock(Schema([Field("a", ColType.INT64)]), [np.array([9])])
    with pytest.raises(IOError):
        pipe_in._check_verify(blk)


def test_link_sim_latency_accounting():
    """The 40 ms-latency experiment's transport knob (section 7.4)."""
    link = LinkSim()
    assert link.delay(1024) == 0.0
    link = LinkSim(latency_s=0.04, bandwidth_bps=8e9)
    d = link.delay(10_000_000)
    assert d >= 0.04


def test_pipe_stats_pool_hits_and_send_overlap():
    """The zero-copy/pipelined hot path must report its own win: pooled
    buffer reuse, copies avoided, and sender-thread overlap all nonzero."""
    from repro.core.iobuf import BufferPool

    block = make_paper_block(600, seed=9, strings=True)
    pool = BufferPool()
    cfg = PipeConfig(mode="arrowcol", block_rows=64, pipelined=True, pool=pool)
    name = "db://stats?query=1"
    got = {}

    def imp():
        pipe = DataPipeInput(name)
        got["rows"] = sum(len(b) for b in pipe.blocks())
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    out = DataPipeOutput(name, config=cfg)
    out.write_block(block)
    out.close()
    t.join(20)
    assert got["rows"] == 600
    assert out.stats.blocks == (600 + 63) // 64
    assert out.stats.pool_hits > 0, "pooled offsets buffers must be reused"
    assert out.stats.copies_avoided > 0, "fixed columns must ship as views"
    assert out.stats.send_overlap_s > 0.0, "sender thread must report overlap"


def test_write_block_roundtrip_with_header_meta():
    """Exporter-side typed fast path: values, header names, and delimiter
    metadata survive without any text serialization."""
    block = make_paper_block(100, seed=11, strings=True)
    name = "db://wblk?query=1"
    got = {}

    def imp():
        pipe = DataPipeInput(name)
        blocks = list(pipe.blocks())
        got["rows"] = sum(len(b) for b in blocks)
        got["meta"] = pipe.meta
        got["first"] = blocks[0].to_rows().rows[0]
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol", block_rows=32))
    out.write_block(block, header=list(block.schema.names), delimiter="|")
    out.close()
    t.join(20)
    assert got["rows"] == 100
    assert got["meta"]["header"] == list(block.schema.names)
    assert got["meta"]["delimiter"] == "|"
    assert got["first"][0] == 0  # key column survives typed


def test_write_block_rejects_schema_mismatch_after_text_rows():
    """Interleaving text writes with a differently-typed block must fail on
    the writer, not corrupt the stream for the reader."""
    name = "db://wblkmix?query=1"

    def imp():
        pipe = DataPipeInput(name)
        try:
            list(pipe.blocks())
        except IOError:
            pass
        pipe.close()

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    # delimiter pinned so the assembler flushes immediately (no sampling)
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol", block_rows=2,
                                                 delimiter=","))
    for _ in range(4):  # forces a flush: 2-column schema goes on the wire
        out.write(AString((1, ",", 2.5, "\n")))
    with pytest.raises(ValueError, match="does not match the"):
        out.write_block(make_paper_block(10, strings=True))  # wider schema
    out.close()
    t.join(10)

    # reverse order: block fixes the stream schema, mismatched text rows
    # must fail at flush instead of decoding against the wrong layout
    name2 = "db://wblkmix2?query=1"

    def imp2():
        pipe = DataPipeInput(name2)
        try:
            list(pipe.blocks())
        except IOError:
            pass
        pipe.close()

    t2 = threading.Thread(target=imp2, daemon=True)
    t2.start()
    out2 = DataPipeOutput(name2, config=PipeConfig(mode="arrowcol", block_rows=2,
                                                   delimiter=","))
    out2.write_block(make_paper_block(10, strings=True))
    with pytest.raises(ValueError, match="does not match the"):
        for _ in range(4):
            out2.write(AString((1, ",", 2.5, "\n")))
    out2.close()  # mismatched rows were consumed by the failed flush
    t2.join(10)


def test_write_block_rejected_on_text_mode():
    """Character rungs cannot carry typed blocks; the exporter must fall
    back to the serializer loop instead."""
    name = "db://wblktext?query=1"

    def imp():
        pipe = DataPipeInput(name)
        pipe.read()
        pipe.close()

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="text"))
    assert not out.accepts_blocks()
    with pytest.raises(ValueError):
        out.write_block(make_paper_block(10))
    out.close()
    t.join(10)


def test_bytes_mode_passthrough():
    name = "db://bin?query=1"
    payload = bytes(range(256)) * 100
    got = {}

    def imp():
        pipe = DataPipeInput(name)
        got["data"] = pipe.read_bytes()
        pipe.close()

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="bytes"))
    out.write(payload)
    out.close()
    t.join(20)
    assert got["data"] == payload
