"""Wire formats + codecs: roundtrip properties over random typed blocks."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.compression import CODECS, get_codec
from repro.core.types import ColType, ColumnBlock, Field, RowBlock, Schema
from repro.core.wire import WIRE_FORMATS, decode_schema, encode_schema, get_wire_format
from repro.engines.base import assert_blocks_equal, make_paper_block

BLOCK_FORMATS = [n for n in WIRE_FORMATS if n not in ("text", "parts")]


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_paper_block_roundtrip(fmt):
    block = make_paper_block(257, seed=1)
    wire = get_wire_format(fmt)
    payload = wire.encode_block(block).join()
    got = wire.decode_block(payload, block.schema)
    assert_blocks_equal(block, got)


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_string_block_roundtrip(fmt):
    block = make_paper_block(64, seed=2, strings=True)
    wire = get_wire_format(fmt)
    got = wire.decode_block(wire.encode_block(block).join(), block.schema)
    assert_blocks_equal(block, got)


def test_schema_frame_roundtrip():
    block = make_paper_block(4)
    payload = encode_schema(block.schema, {"mode": "arrowcol", "delimiter": "|"})
    schema, meta = decode_schema(payload)
    assert schema.names == block.schema.names
    assert meta["delimiter"] == "|"


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_codec_roundtrip(codec):
    c = get_codec(codec)
    data = b"abc" * 1000 + bytes(range(256)) * 7
    assert c.decompress(c.compress(data)) == data


@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=50, deadline=None)
def test_rle_roundtrip_property(data):
    c = get_codec("rle")
    assert c.decompress(c.compress(data)) == data


@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=30, deadline=None)
def test_zstd_roundtrip_property(data):
    c = get_codec("zstd")
    assert c.decompress(c.compress(data)) == data


_col = st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=40)


@given(_col, st.sampled_from(BLOCK_FORMATS))
@settings(max_examples=40, deadline=None)
def test_int_column_roundtrip_property(ints, fmt):
    schema = Schema([Field("a", ColType.INT64)])
    block = ColumnBlock(schema, [np.asarray(ints, np.int64)])
    wire = get_wire_format(fmt)
    got = wire.decode_block(wire.encode_block(block).join(), schema)
    np.testing.assert_array_equal(np.asarray(got.columns[0]), ints)


@given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                          width=64), min_size=1, max_size=40),
       st.sampled_from(BLOCK_FORMATS))
@settings(max_examples=40, deadline=None)
def test_float_column_bitexact_property(vals, fmt):
    schema = Schema([Field("x", ColType.FLOAT64)])
    block = ColumnBlock(schema, [np.asarray(vals, np.float64)])
    wire = get_wire_format(fmt)
    got = wire.decode_block(wire.encode_block(block).join(), schema)
    np.testing.assert_array_equal(np.asarray(got.columns[0]),
                                  np.asarray(vals, np.float64))
