"""Property-based round-trip suite for the five wire formats.

Hypothesis drives arbitrary column mixes, row counts (including zero),
max-width strings, and NaN/inf floats through encode -> segments -> decode
and requires **bit-identical** survival; on boxes without hypothesis the
property tests degrade to skips (tests/hypothesis_fallback.py) while the
deterministic edge-case tests below still run everywhere.

The block formats (arrowcol, arrowrow, binary_rows, tagged) round-trip
ColumnBlocks; parts_rows round-trips its native unit, typed part rows
(its ColumnBlock shim goes through delimiter re-parsing and is exercised
separately with delimiter-safe data).
"""

import math
import struct

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.iobuf import BufferPool, DecodeArena
from repro.core.types import ColType, ColumnBlock, Field, Schema
from repro.core.wire import get_wire_format
from repro.core.wire.parts_rows import PartsRowsFormat

BLOCK_FORMATS = ["arrowcol", "arrowrow", "binary_rows", "tagged"]

_I32 = 2**31
_I64 = 2**63


def _bits(a: np.ndarray) -> np.ndarray:
    """Bit pattern view for exact float comparison (NaN payloads count)."""
    if a.dtype == np.float64:
        return a.view(np.uint64)
    if a.dtype == np.float32:
        return a.view(np.uint32)
    return a


def assert_bit_identical(a: ColumnBlock, b: ColumnBlock) -> None:
    assert a.schema.types == b.schema.types
    assert len(a) == len(b)
    for f, ca, cb in zip(a.schema, a.columns, b.columns):
        if f.type is ColType.STRING:
            assert list(ca) == list(cb), f"column {f.name}"
        else:
            xa = np.asarray(ca, f.type.np_dtype)
            xb = np.asarray(cb, f.type.np_dtype)
            np.testing.assert_array_equal(_bits(xa), _bits(xb),
                                          err_msg=f"column {f.name}")


def _roundtrip(fmt: str, block: ColumnBlock, arena=None) -> None:
    wire = get_wire_format(fmt)
    segs = wire.encode_block(block, pool=BufferPool())
    payload = segs.join()
    segs.release()
    # decode twice: from plain bytes, and in place from a memoryview (the
    # shm-ring read path); both must agree bit for bit
    got_bytes = wire.decode_block(payload, block.schema, arena=arena)
    got_view = wire.decode_block(memoryview(payload), block.schema,
                                 arena=arena)
    assert_bit_identical(block, got_bytes)
    assert_bit_identical(block, got_view)


# -- strategies ---------------------------------------------------------------------

_string = st.text(max_size=48)


def _column(ct, n):
    if ct is ColType.STRING:
        return st.lists(_string, min_size=n, max_size=n)
    if ct is ColType.BOOL:
        elems = st.booleans()
    elif ct is ColType.INT32:
        elems = st.integers(-_I32, _I32 - 1)
    elif ct is ColType.INT64:
        elems = st.integers(-_I64, _I64 - 1)
    elif ct is ColType.FLOAT32:
        elems = st.floats(width=32, allow_nan=False, allow_infinity=True)
    else:
        elems = st.floats(width=64, allow_nan=True, allow_infinity=True)
    return st.lists(elems, min_size=n, max_size=n)


@st.composite
def column_blocks(draw):
    """Arbitrary column mixes, including zero-row and zero-column blocks."""
    ncols = draw(st.integers(0, 5))
    nrows = draw(st.integers(0, 40))
    fields, cols = [], []
    for i in range(ncols):
        ct = draw(st.sampled_from(list(ColType)))
        fields.append(Field(f"c{i}", ct))
        vals = draw(_column(ct, nrows))
        cols.append(vals if ct is ColType.STRING
                    else np.asarray(vals, ct.np_dtype))
    return ColumnBlock(Schema(fields), cols)


_part = st.one_of(
    st.booleans(),
    st.integers(-_I64, _I64 - 1),
    st.floats(width=64, allow_nan=True, allow_infinity=True),
    st.text(max_size=32),
)


# -- hypothesis properties ----------------------------------------------------------


@given(column_blocks(), st.sampled_from(BLOCK_FORMATS))
@settings(max_examples=60, deadline=None)
def test_block_roundtrip_property(block, fmt):
    _roundtrip(fmt, block)


@given(column_blocks(), st.sampled_from(BLOCK_FORMATS))
@settings(max_examples=30, deadline=None)
def test_block_roundtrip_property_with_arena(block, fmt):
    _roundtrip(fmt, block, arena=DecodeArena(BufferPool()))


@given(st.lists(st.lists(_part, max_size=12), max_size=24))
@settings(max_examples=60, deadline=None)
def test_parts_rows_roundtrip_property(part_rows):
    wire = PartsRowsFormat()
    segs = wire.encode_parts(part_rows, pool=BufferPool())
    payload = segs.join()
    segs.release()
    for data in (payload, memoryview(payload)):
        got = [tuple(a.parts) for a in wire.decode_parts(data)]
        assert len(got) == len(part_rows)
        for want_row, got_row in zip(part_rows, got):
            assert len(want_row) == len(got_row)
            for w, g in zip(want_row, got_row):
                assert type(g) is type(w)
                if isinstance(w, float):
                    assert struct.pack("<d", w) == struct.pack("<d", g)
                else:
                    assert w == g


@given(st.binary(max_size=2048), st.integers(64, 333))
@settings(max_examples=40, deadline=None)
def test_shm_ring_frame_roundtrip_property(payload, capacity_step):
    """Arbitrary payloads through a deliberately tiny ring: the frame must
    survive the wrap-marker path bit for bit."""
    from repro.core.shm_ring import ShmRing, ShmRingTransport
    from repro.core.transport import FRAME_BLOCK

    ring = ShmRing.create(capacity=2048 + 5 + capacity_step, role="reader")
    try:
        tx, rx = ShmRingTransport(ring), ShmRingTransport(ring)
        for chunk in range(3):  # repeat so the cursor walks into a wrap
            tx.send_frames(FRAME_BLOCK, [payload])
            kind, got = rx.recv_frame()
            assert kind == FRAME_BLOCK and bytes(got) == payload
    finally:
        ring.close()


# -- deterministic edge cases (run even without hypothesis) -------------------------


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_zero_row_block_roundtrip(fmt):
    schema = Schema.of(("a", ColType.INT64), ("s", ColType.STRING),
                       ("x", ColType.FLOAT64))
    block = ColumnBlock(schema, [np.empty(0, np.int64), [],
                                 np.empty(0, np.float64)])
    _roundtrip(fmt, block)


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_empty_block_roundtrip(fmt):
    _roundtrip(fmt, ColumnBlock(Schema([]), []))


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_nan_inf_floats_bit_identical(fmt):
    vals = np.array([0.0, -0.0, math.inf, -math.inf, math.nan,
                     np.float64(1e308), 5e-324], np.float64)
    # a NaN with a non-default payload must survive too
    vals = np.concatenate([vals, np.array([0x7FF80000DEADBEEF],
                                          np.uint64).view(np.float64)])
    block = ColumnBlock(Schema.of(("x", ColType.FLOAT64)), [vals])
    _roundtrip(fmt, block)


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_max_width_strings_roundtrip(fmt):
    big = "\N{SNOWMAN}" * 33000 + "tail"   # multi-byte utf8, >64 KiB heap
    wide = ["", "x" * 65535, big, "plain"]
    block = ColumnBlock(
        Schema.of(("k", ColType.INT32), ("s", ColType.STRING)),
        [np.arange(4, dtype=np.int32), wide],
    )
    _roundtrip(fmt, block)


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_int_extremes_roundtrip(fmt):
    block = ColumnBlock(
        Schema.of(("i32", ColType.INT32), ("i64", ColType.INT64),
                  ("b", ColType.BOOL)),
        [np.array([-_I32, _I32 - 1, 0, -1], np.int32),
         np.array([-_I64, _I64 - 1, 0, -1], np.int64),
         np.array([True, False, True, False])],
    )
    _roundtrip(fmt, block)


@pytest.mark.parametrize("fmt", BLOCK_FORMATS)
def test_decoded_columns_never_alias_wire_buffer(fmt):
    """Without an arena, decode output must own its memory: a column view
    into the wire buffer would be corrupted when a transport span is
    recycled (regression: single-fixed-column arrowrow returned a view)."""
    schema = Schema.of(("a", ColType.INT64))
    block = ColumnBlock(schema, [np.arange(16, dtype=np.int64)])
    wire = get_wire_format(fmt)
    payload = bytearray(wire.encode_block(block).join())
    got = wire.decode_block(memoryview(payload), schema)
    snapshot = np.asarray(got.columns[0]).copy()
    payload[:] = b"\xff" * len(payload)  # simulate span recycling
    np.testing.assert_array_equal(np.asarray(got.columns[0]), snapshot)


def test_parts_rows_edges_deterministic():
    wire = PartsRowsFormat()
    rows = [[], [True, False], [0, -(2**63), 2**63 - 1],
            [math.inf, -0.0], ["", ",", "a" * 70000, "néwliné\n"]]
    payload = wire.encode_parts(rows).join()
    got = [list(a.parts) for a in wire.decode_parts(memoryview(payload))]
    assert got[0] == [] and got[1] == [True, False]
    assert got[2] == [0, -(2**63), 2**63 - 1]
    assert got[3][0] == math.inf and struct.pack("<d", got[3][1]) == \
        struct.pack("<d", -0.0)
    assert got[4] == ["", ",", "a" * 70000, "néwliné\n"]
