"""Distributed-infrastructure paths: directory server RPC, elastic
restart across device counts, straggler hedging, compressed reduction."""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.directory import (
    DirectoryClient,
    DirectoryServer,
    Endpoint,
)


def test_directory_server_rpc_roundtrip():
    """The out-of-process worker directory (multi-host deployments)."""
    server = DirectoryServer().start()
    try:
        client = DirectoryClient(server.host, server.port)
        got = {}

        def exporter():
            got["ep"] = client.query("ds", "q1", timeout=10)

        t = threading.Thread(target=exporter)
        t.start()
        time.sleep(0.05)
        client.register("ds", Endpoint("127.0.0.1", 12345), "q1")
        t.join(10)
        assert got["ep"].host == "127.0.0.1" and got["ep"].port == 12345
    finally:
        server.stop()


def test_directory_server_timeout():
    server = DirectoryServer().start()
    try:
        client = DirectoryClient(server.host, server.port)
        with pytest.raises((TimeoutError, IOError)):
            client.query("nobody", "q", timeout=0.3)
    finally:
        server.stop()


def test_feeder_abandons_stalled_source():
    """Straggler mitigation: a source that never delivers is abandoned and
    the stream still terminates."""
    from repro.core.datapipe import DataPipeOutput, PipeConfig
    from repro.pipeline import PipeFeeder, SyntheticSource

    names = ["db://fast?query=s", "db://stall?query=s"]
    feeder = PipeFeeder(names, batch_size=2, seq_len=4,
                        hedge_timeout=0.5).start()

    def fast():
        SyntheticSource(32, 4, seed=0).serve(names[0], 6)

    def stall():
        # register + connect, send schema, then hang past the hedge window
        out = DataPipeOutput(names[1], config=PipeConfig())
        time.sleep(1.2)
        out.close()

    t1 = threading.Thread(target=fast, daemon=True)
    t2 = threading.Thread(target=stall, daemon=True)
    t1.start(); t2.start()
    batches = list(feeder.batches())
    assert sum(b.data["tokens"].shape[0] for b in batches) >= 6


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, get_config
from repro.train import CheckpointManager, TrainState, adamw_init
from repro.train.step import train_state_specs
from repro.distrib.sharding import named_sharding

cfg = get_config("smollm-360m").reduced()
model = build_model(cfg)
ckpt = sys.argv[1]
phase = sys.argv[2]
mesh_shape = (4, 2) if phase == "save" else (2, 4)   # elastic re-mesh
from repro.launch.mesh import _axis_type_kwargs
mesh = jax.make_mesh(mesh_shape, ("data", "model"), **_axis_type_kwargs(2))
params = model.init(jax.random.PRNGKey(0))
state = TrainState(params, adamw_init(params))
specs = train_state_specs(state, mesh, cfg)
shardings = named_sharding(mesh, specs)
state = jax.device_put(state, shardings)   # sharded on this mesh
mgr = CheckpointManager(ckpt)
if phase == "save":
    mgr.save(11, state)
    print("SAVED", 11)
else:
    restored, step = mgr.restore(jax.eval_shape(lambda: state))
    restored = jax.device_put(restored, shardings)  # reshard on new mesh
    a = jax.tree_util.tree_leaves(state.params)[0]
    b = jax.tree_util.tree_leaves(restored.params)[0]
    import numpy as np
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("RESTORED", step)
"""


@pytest.mark.slow
def test_elastic_checkpoint_across_mesh_shapes(tmp_path):
    """Save on a (4,2) mesh, restore + reshard on a (2,4) mesh (elastic
    re-mesh after a device-count change)."""
    script = tmp_path / "elastic.py"
    script.write_text(ELASTIC_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    ckpt = str(tmp_path / "ck")
    r1 = subprocess.run([sys.executable, str(script), ckpt, "save"],
                        capture_output=True, text=True, env=env, timeout=300)
    assert "SAVED 11" in r1.stdout, r1.stderr[-1500:]
    r2 = subprocess.run([sys.executable, str(script), ckpt, "restore"],
                        capture_output=True, text=True, env=env, timeout=300)
    assert "RESTORED 11" in r2.stdout, r2.stderr[-1500:]


def test_compressed_psum_matches_fullprec_within_tolerance():
    """q8 cross-pod gradient compression: sum of dequantized shards must
    track the exact sum within blockwise-quantization error."""
    import jax
    import jax.numpy as jnp

    from repro.distrib.compress import dequantize_q8, quantize_q8

    rng = jax.random.PRNGKey(0)
    shards = [jax.random.normal(jax.random.fold_in(rng, i), (2048,))
              for i in range(4)]
    exact = sum(np.asarray(s) for s in shards)
    approx = np.zeros_like(exact)
    max_scale = 0.0
    for s in shards:
        q, scale = quantize_q8(s)
        approx += np.asarray(dequantize_q8(q, scale, s.shape, jnp.float32))
        max_scale = max(max_scale, float(scale.max()))
    err = np.abs(exact - approx).max()
    assert err <= 4 * (max_scale * 0.5 + 1e-6)
