"""Input pipeline (pipe-fed) and serving engine."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.datapipe import PipeConfig
from repro.models import build_model, get_config
from repro.pipeline import PipeFeeder, SyntheticSource
from repro.serve import ServeEngine


@pytest.fixture
def fresh_jax():
    """Isolate the jax PRNG/compile-cache interaction: drop every cached
    executable left behind by earlier tests so both runs inside the test
    compile (and autotune) from the same clean slate, and hand each test
    its own key instead of a module-level one."""
    jax.clear_caches()
    yield jax.random.PRNGKey(0)


def test_pipe_feeder_delivers_batches():
    seq, bsz, vocab = 8, 4, 100
    name = "db://feed?query=f1"
    feeder = PipeFeeder([name], batch_size=bsz, seq_len=seq).start()
    src = SyntheticSource(vocab, seq, seed=1)
    t = threading.Thread(target=src.serve, args=(name, 20),
                         kwargs={"config": PipeConfig(block_rows=8)})
    t.start()
    batches = list(feeder.batches())
    t.join(20)
    assert len(batches) == 5  # 20 rows / 4
    for b in batches:
        assert b.data["tokens"].shape == (bsz, seq)
        assert b.data["tokens"].max() < vocab
        np.testing.assert_array_equal(
            b.data["labels"][:, :-1], b.data["tokens"][:, 1:])
    assert [b.batch_id for b in batches] == [0, 1, 2, 3, 4]


def test_pipe_feeder_skip_until_restart():
    """Deterministic restart: skip_until fast-forwards past done batches."""
    seq, bsz, vocab = 8, 2, 50
    name = "db://feed2?query=f1"
    feeder = PipeFeeder([name], batch_size=bsz, seq_len=seq,
                        skip_until=3).start()
    src = SyntheticSource(vocab, seq, seed=2)
    t = threading.Thread(target=src.serve, args=(name, 10))
    t.start()
    batches = list(feeder.batches())
    t.join(20)
    assert [b.batch_id for b in batches] == [3, 4]


def test_feeder_merges_multiple_sources():
    seq, bsz = 8, 4
    names = ["db://multi?query=a", "db://multi2?query=b"]
    feeder = PipeFeeder(names, batch_size=bsz, seq_len=seq).start()
    threads = [
        threading.Thread(target=SyntheticSource(64, seq, seed=i).serve,
                         args=(n, 6))
        for i, n in enumerate(names)
    ]
    for t in threads:
        t.start()
    batches = list(feeder.batches())
    for t in threads:
        t.join(20)
    assert sum(b.data["tokens"].shape[0] for b in batches) == 12


def test_serve_engine_continuous_batching():
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, max_context=64,
                      eos_token=-1)  # never hit eos
    rids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(5)]
    results = eng.run(max_steps=200)
    assert len(results) == 5
    by_id = {r.request_id: r for r in results}
    assert set(by_id) == set(rids)
    for r in results:
        assert len(r.tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.tokens)


def test_serve_engine_greedy_deterministic(fresh_jax):
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg)
    params = model.init(fresh_jax)

    def run_once():
        # regression guard for the token-buffer aliasing race: ServeEngine
        # must copy _tokens at dispatch (jnp.array), or the async step
        # reads the buffer while the loop mutates it and this diverges
        eng = ServeEngine(model, params, batch_size=1, max_context=32)
        eng.submit([5, 6], max_new_tokens=6)
        return eng.run(max_steps=50)[0].tokens

    assert run_once() == run_once()
