"""System tests: full engine-to-engine transfers through generated pipes."""

import numpy as np
import pytest

from repro.core import PipeConfig, transfer, transfer_via_files
from repro.engines import ENGINES, make_engine, make_paper_block

PAIRS = [(s, d) for s in ENGINES for d in ENGINES if s != d]


def _check(src_block, dst, table, n):
    rows = dst.get_block(table).to_rows().rows
    assert len(rows) == n
    vals = np.sort(np.array([float(r[2]) for r in rows]))
    want = np.sort(np.asarray(src_block.columns[2], float))
    np.testing.assert_allclose(vals, want, atol=1e-12)


@pytest.mark.parametrize("pair", PAIRS, ids=[f"{s}->{d}" for s, d in PAIRS])
def test_pair_arrowcol(pair):
    s, d = pair
    src, dst = make_engine(s), make_engine(d)
    blk = make_paper_block(200, seed=3)
    src.put_block("t", blk)
    r = transfer(src, "t", dst, "t2",
                 config=PipeConfig(mode="arrowcol", block_rows=64), timeout=30)
    assert r.rows == 200
    _check(blk, dst, "t2", 200)


@pytest.mark.parametrize("mode", ["text", "parts", "binary_rows", "tagged",
                                  "arrowrow", "arrowcol"])
def test_modes_colstore_to_dataframe(mode):
    src, dst = make_engine("colstore"), make_engine("dataframe")
    blk = make_paper_block(150, seed=5)
    src.put_block("t", blk)
    transfer(src, "t", dst, "t2",
             config=PipeConfig(mode=mode, block_rows=32), timeout=30)
    _check(blk, dst, "t2", 150)


@pytest.mark.parametrize("codec", ["none", "rle", "zip", "zstd"])
def test_codecs(codec):
    from repro.core.compression import CODECS

    if codec not in CODECS:
        pytest.skip(f"codec {codec!r} not available (optional dependency)")
    src, dst = make_engine("colstore"), make_engine("dataframe")
    blk = make_paper_block(150, seed=6)
    src.put_block("t", blk)
    transfer(src, "t", dst, "t2",
             config=PipeConfig(codec=codec, block_rows=32), timeout=30)
    _check(blk, dst, "t2", 150)


def test_parallel_workers_4x4():
    src = make_engine("colstore", workers=4)
    dst = make_engine("dataframe", workers=4)
    blk = make_paper_block(2000, seed=7)
    src.put_block("t", blk)
    r = transfer(src, "t", dst, "t2", workers=4, timeout=60)
    assert r.rows == 2000
    _check(blk, dst, "t2", 2000)


def test_worker_mismatch_2_exporters_4_importers():
    src = make_engine("colstore", workers=2)
    dst = make_engine("dataframe", workers=4)
    blk = make_paper_block(1000, seed=8)
    src.put_block("t", blk)
    r = transfer(src, "t", dst, "t2", workers=2, import_workers=4, timeout=60)
    assert r.rows == 1000


def test_concurrent_transfers_do_not_collide():
    """Distinct query ids keep simultaneous transfers apart (section 4.2)."""
    import threading

    src1, dst1 = make_engine("colstore"), make_engine("dataframe")
    src2, dst2 = make_engine("rowstore"), make_engine("graphstore")
    b1, b2 = make_paper_block(300, seed=9), make_paper_block(200, seed=10)
    src1.put_block("t", b1)
    src2.put_block("t", b2)
    errs = []

    def run(src, dst, n):
        try:
            r = transfer(src, "t", dst, "t2", timeout=60)
            assert r.rows == n, r.rows
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    t1 = threading.Thread(target=run, args=(src1, dst1, 300))
    t2 = threading.Thread(target=run, args=(src2, dst2, 200))
    t1.start(); t2.start(); t1.join(60); t2.join(60)
    assert not errs, errs


def test_file_baseline_equivalence():
    """Pipe transfer lands the same data as the file-system baseline."""
    src1, dst1 = make_engine("colstore"), make_engine("dataframe")
    src2, dst2 = make_engine("colstore"), make_engine("dataframe")
    blk = make_paper_block(200, seed=11)
    src1.put_block("t", blk)
    src2.put_block("t", blk)
    transfer(src1, "t", dst1, "t2", timeout=30)
    transfer_via_files(src2, "t", dst2, "t2")
    a = dst1.get_block("t2").to_rows().rows
    b = dst2.get_block("t2").to_rows().rows
    assert sorted(map(repr, a)) == sorted(map(repr, b))


def test_seqfile_shared_binary_format():
    """Section 5: a shared binary format pipes straight through (bytes)."""
    import threading

    from repro.core import PipeEnabledEngine, adapter_for

    src, dst = make_engine("mapreduce"), make_engine("mapreduce")
    blk = make_paper_block(300, seed=12)
    src.put_block("t", blk)
    gp = adapter_for(src)
    errs = []

    def imp():
        try:
            with PipeEnabledEngine(gp):
                dst.import_csv("t2", "db://seqx?query=s1")  # sniffs magic
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    def exp():
        try:
            with PipeEnabledEngine(gp):
                src.export_seqfile("t", "db://seqx?query=s1")
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    ti = threading.Thread(target=imp)
    te = threading.Thread(target=exp)
    ti.start(); te.start(); ti.join(30); te.join(30)
    assert not errs, errs
    assert len(dst.get_block("t2")) == 300


def test_json_library_extension_transfer():
    """Section 5.2: jsonlib (Jackson analog) export -> typed import."""
    import threading

    from repro.core import PipeEnabledEngine, adapter_for
    from repro.core.ioredirect import PipeOpenContext

    src, dst = make_engine("dataframe"), make_engine("colstore")
    blk = make_paper_block(250, seed=13)
    src.put_block("t", blk)
    cfg = PipeConfig(mode="arrowcol", text_format="json", block_rows=64)
    errs = []

    def imp():
        try:
            with PipeEnabledEngine(adapter_for(dst)), PipeOpenContext(cfg):
                dst.import_json("t2", "db://jx?query=j1")
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    def exp():
        try:
            with PipeEnabledEngine(adapter_for(src)), PipeOpenContext(cfg):
                src.export_json("t", "db://jx?query=j1")
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    ti = threading.Thread(target=imp)
    te = threading.Thread(target=exp)
    ti.start(); te.start(); ti.join(30); te.join(30)
    assert not errs, errs
    assert len(dst.get_block("t2")) == 250
