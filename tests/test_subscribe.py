"""Continuous pipes (repro.core.subscribe): epochs, the replay log,
broadcast fan-out with one encode per epoch, late-joiner replay vs
snapshot fallback, slow-subscriber retention eviction, the named
publication registry (in-process AND over the directory RPC), renewer
leak-freedom, the plan subscribe() verb, pipetop's subscriptions table,
and the serving-path FeatureView."""

import threading
import time

import pytest

from repro.core import subscribe as subm
from repro.core.directory import (
    DirectoryClient,
    DirectoryServer,
    LeaseRenewer,
    WorkerDirectory,
    live_renewers,
)
from repro.core.plan import PlanError, plan
from repro.core.subscribe import (
    PublicationEnded,
    ReplayLog,
    _EpochRecord,
    publications_snapshot,
    publish,
    subscribe,
)
from repro.engines import (
    ColStore,
    RowStore,
    assert_blocks_equal,
    make_paper_block,
)

JOIN_S = 30


def _drain(sub, want, timeout=15.0):
    """Poll until ``want`` epochs arrived (or fail the test)."""
    out = []
    deadline = time.monotonic() + timeout
    while len(out) < want and time.monotonic() < deadline:
        out.extend(sub.poll(timeout=0.2))
    assert len(out) >= want, f"got {len(out)} of {want} epochs"
    return out


# -- replay log ------------------------------------------------------------------


def test_replay_log_epoch_cap_evicts_oldest():
    log = ReplayLog(retain_epochs=3, retain_bytes=1 << 30)
    for e in range(1, 6):
        log.append(_EpochRecord(e, "delta", [b"x" * 10], 1, 10, 0.0))
    assert len(log) == 3
    assert log.floor == 3
    assert log.get(2) is None and log.get(5) is not None
    assert log.evicted == 2


def test_replay_log_byte_cap_keeps_newest():
    log = ReplayLog(retain_epochs=100, retain_bytes=25)
    for e in range(1, 5):
        log.append(_EpochRecord(e, "delta", [b"x" * 10], 1, 10, 0.0))
    # 4 x 10B under a 25B cap -> two retained; newest always kept
    assert log.get(4) is not None
    assert log.nbytes <= 25
    # one oversized record still lands (the live path never starves)
    log.append(_EpochRecord(9, "delta", [b"y" * 100], 1, 100, 0.0))
    assert log.get(9) is not None


# -- single-subscriber basics ----------------------------------------------------


def test_publish_subscribe_initial_snapshot_then_deltas():
    d = WorkerDirectory()
    base = make_paper_block(64, seed=1)
    pub = publish("t.basic", initial=base, directory=d)
    sub = subscribe("t.basic", directory=d, transport="shm")
    try:
        first = _drain(sub, 1)
        assert first[0].kind == "snapshot" and first[0].epoch == 1
        assert_blocks_equal(first[0].block, base)
        deltas = [make_paper_block(8, seed=10 + i) for i in range(3)]
        for b in deltas:
            pub.append(b)
        got = _drain(sub, 3)
        assert [e.epoch for e in got] == [2, 3, 4]
        for e, b in zip(got, deltas):
            assert e.kind == "delta"
            assert_blocks_equal(e.block, b)
        assert sub.watermark == 4 and sub.lag_epochs == 0
    finally:
        sub.close()
        pub.close()


@pytest.mark.parametrize("transport", ["channel", "socket"])
def test_transport_matrix_delivers_epochs(transport):
    d = WorkerDirectory()
    pub = publish(f"t.{transport}", initial=make_paper_block(32, seed=2),
                  directory=d)
    sub = subscribe(f"t.{transport}", directory=d, transport=transport)
    try:
        pub.append(make_paper_block(8, seed=3))
        got = _drain(sub, 2)
        assert [e.epoch for e in got] == [1, 2]
    finally:
        sub.close()
        pub.close()


def test_striped_subscription_preserves_epoch_order():
    d = WorkerDirectory()
    pub = publish("t.striped", initial=make_paper_block(64, seed=4),
                  directory=d)
    sub = subscribe("t.striped", directory=d, transport="socket", streams=3)
    try:
        blocks = [make_paper_block(16, seed=20 + i) for i in range(10)]
        for b in blocks:
            pub.append(b)
        got = _drain(sub, 11)
        assert [e.epoch for e in got] == list(range(1, 12))
        for e, b in zip(got[1:], blocks):
            assert_blocks_equal(e.block, b)
    finally:
        sub.close()
        pub.close()


def test_poll_raises_publication_ended_after_drain():
    d = WorkerDirectory()
    pub = publish("t.ended", initial=make_paper_block(16, seed=5),
                  directory=d)
    sub = subscribe("t.ended", directory=d, transport="shm")
    try:
        _drain(sub, 1)
        pub.append(make_paper_block(4, seed=6))
        pub.close()  # graceful: drains epoch 2, then EOF
        got = _drain(sub, 1)
        assert got[-1].epoch == 2
        with pytest.raises(PublicationEnded) as ei:
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                sub.poll(timeout=0.2)
        assert ei.value.watermark == 2  # resubscribe point
    finally:
        sub.close()


# -- broadcast fan-out (acceptance: 50 epochs x 3 subscribers, 1 encode) ---------


def test_broadcast_3sub_50_epochs_bit_identical_one_encode():
    d = WorkerDirectory()
    pub = publish("t.bc", schema=make_paper_block(1).schema, directory=d,
                  retain_epochs=128)
    subs = [subscribe("t.bc", directory=d, transport="shm", broadcast=3)
            for _ in range(3)]
    try:
        blocks = [make_paper_block(32, seed=i) for i in range(50)]
        for b in blocks:
            pub.append(b)
        got = [[] for _ in subs]
        deadline = time.monotonic() + 30
        while (any(len(g) < 50 for g in got)
               and time.monotonic() < deadline):
            for g, s in zip(got, subs):
                g.extend(s.poll(timeout=0.1))
        for g in got:
            assert len(g) == 50
            assert [e.epoch for e in g] == list(range(1, 51))
            for e, b in zip(g, blocks):
                assert_blocks_equal(e.block, b)  # bit-identical fan-out
        # the broadcast path encodes each epoch exactly once
        assert pub.stats.encodes == 50
        assert pub.stats.snapshot_fallbacks == 0
        assert pub.subscribers == 3
    finally:
        for s in subs:
            s.close()
        pub.close()


# -- late joiners: replay vs snapshot fallback -----------------------------------


def test_late_joiner_at_epoch_30_replays_without_snapshot():
    d = WorkerDirectory()
    pub = publish("t.late", schema=make_paper_block(1).schema, directory=d,
                  retain_epochs=100)
    try:
        blocks = [make_paper_block(16, seed=i) for i in range(50)]
        for b in blocks:
            pub.append(b)
        sub = subscribe("t.late", directory=d, transport="shm",
                        watermark=30)
        try:
            got = _drain(sub, 20)
            assert [e.epoch for e in got] == list(range(31, 51))
            assert all(e.kind == "delta" for e in got)
            for e, b in zip(got, blocks[30:]):
                assert_blocks_equal(e.block, b)
            # replayed from the log — never a full snapshot
            assert pub.stats.snapshot_fallbacks == 0
            assert pub.stats.replayed_epochs == 20
        finally:
            sub.close()
    finally:
        pub.close()


def test_late_joiner_below_retention_gets_snapshot_fallback():
    d = WorkerDirectory()
    pub = publish("t.snap", schema=make_paper_block(1).schema, directory=d,
                  retain_epochs=5)
    try:
        blocks = [make_paper_block(16, seed=i) for i in range(40)]
        for b in blocks:
            pub.append(b)
        assert pub._log.floor == 36  # epochs 1..35 evicted
        sub = subscribe("t.snap", directory=d, transport="shm",
                        watermark=10)
        try:
            got = _drain(sub, 1)
            snap = got[0]
            assert snap.kind == "snapshot"
            assert snap.epoch == 40  # stamped with the image's epoch
            assert len(snap.block) == sum(len(b) for b in blocks)
            assert pub.stats.snapshot_fallbacks == 1
            assert pub.stats.fallback_encodes == 1
            # live deltas continue after the snapshot
            pub.append(make_paper_block(4, seed=99))
            nxt = _drain(sub, 1)
            assert nxt[0].epoch == 41 and nxt[0].kind == "delta"
            assert sub.watermark == 41
        finally:
            sub.close()
    finally:
        pub.close()


def test_slow_subscriber_retention_eviction_heals_via_snapshot():
    """A subscriber that stops polling stops draining its ring (bounded
    receive queue -> the publisher's sender blocks); the publisher keeps
    committing and the log evicts past the stalled watermark.  When the
    subscriber resumes, the sender heals it with a snapshot instead of
    wedging — and the folded result is complete."""
    from repro.core.types import ColumnBlock

    d = WorkerDirectory()
    pub = publish("t.slow", schema=make_paper_block(1).schema, directory=d,
                  retain_epochs=4)
    # small ring + 2-epoch receive queue: backpressure builds immediately
    sub = subscribe("t.slow", directory=d, transport="shm",
                    shm_capacity=1 << 16, queue_max=2)
    try:
        blocks = [make_paper_block(512, seed=i) for i in range(30)]
        for b in blocks:
            pub.append(b)
        deadline = time.monotonic() + 15
        while pub._log.evicted == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert pub._log.evicted > 0  # retention dropped stalled epochs
        got = []
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            got.extend(sub.poll(timeout=0.2))
            if got and got[-1].epoch >= 30:
                break
        assert got and got[-1].epoch == 30
        assert any(e.kind == "snapshot" for e in got)
        assert pub.stats.snapshot_fallbacks >= 1
        # fold what arrived: the snapshot supersedes the gap, deltas
        # extend it — the subscriber ends bit-complete anyway
        folded = None
        for e in got:
            folded = (e.block if (e.kind == "snapshot" or folded is None)
                      else ColumnBlock.concat([folded, e.block]))
        assert folded is not None and len(folded) == 30 * 512
        assert_blocks_equal(folded, ColumnBlock.concat(blocks))
    finally:
        sub.close()
        pub.close()


# -- renewer ownership (the satellite fix) ---------------------------------------


def test_lease_renewer_owned_by_handle_no_leak_after_close():
    d = WorkerDirectory(lease_ttl=0.5)
    base = live_renewers()
    pub = publish("t.lease", initial=make_paper_block(16, seed=7),
                  directory=d, lease_s=0.5)
    sub = subscribe("t.lease", directory=d, transport="shm", lease_s=0.5)
    assert live_renewers() == base + 2  # one per handle, long-lived
    _drain(sub, 1)
    # renewal outlives any single transfer: the registration stays fresh
    time.sleep(1.2)
    assert d.renew_name("t.lease", lease_s=0.5) == 1
    sub.close()
    pub.close()
    deadline = time.monotonic() + 5
    while live_renewers() > base and time.monotonic() < deadline:
        time.sleep(0.02)
    assert live_renewers() == base  # no renewal leak after close


def test_lease_renewer_on_lost_fires_and_thread_exits():
    lost = threading.Event()
    calls = []

    def renew(lease_s):
        calls.append(lease_s)
        return 0  # gone on first heartbeat

    r = LeaseRenewer(renew, 0.15, on_lost=lost.set).start()
    assert lost.wait(5.0)
    deadline = time.monotonic() + 5
    while r.alive and time.monotonic() < deadline:
        time.sleep(0.02)
    assert not r.alive and r.lost.is_set() and calls
    r.stop()


# -- named publication registry --------------------------------------------------


def test_publication_registry_in_process():
    d = WorkerDirectory()
    d.publish_name("reg.a", {"pid": 0, "k": "v"})
    doc = d.lookup_name("reg.a", timeout=5.0)
    assert doc["k"] == "v"
    assert "reg.a" in d.list_names()
    assert d.renew_name("reg.a", lease_s=1.0) in (0, 1)  # no-ttl registry
    assert d.unpublish_name("reg.a")
    with pytest.raises(TimeoutError):
        d.lookup_name("reg.a", timeout=0.1)


def test_publication_registry_lease_expiry_gc():
    d = WorkerDirectory(lease_ttl=0.2)
    d.publish_name("reg.exp", {"pid": 0}, lease_s=0.2)
    time.sleep(0.5)
    with pytest.raises(TimeoutError):
        d.lookup_name("reg.exp", timeout=0.1)
    assert d.renew_name("reg.exp") == 0  # strictly gone


def test_publication_registry_over_directory_rpc():
    d = WorkerDirectory()
    server = DirectoryServer(directory=d)
    server.start()
    try:
        client = DirectoryClient("127.0.0.1", server.port)
        client.publish_name("reg.rpc", {"mode": "arrowcol"})
        doc = client.lookup_name("reg.rpc", timeout=5.0)
        assert doc["mode"] == "arrowcol"
        assert client.renew_name("reg.rpc") in (0, 1)
        assert "reg.rpc" in client.list_names()
        assert client.unpublish_name("reg.rpc")
        with pytest.raises(TimeoutError):
            client.lookup_name("reg.rpc", timeout=0.1)
    finally:
        server.stop()


def test_lookup_blocks_until_published():
    d = WorkerDirectory()
    out = {}

    def waiter():
        out["doc"] = d.lookup_name("reg.blk", timeout=10.0)

    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.1)
    d.publish_name("reg.blk", {"pid": 0, "x": 1})
    t.join(JOIN_S)
    assert not t.is_alive() and out["doc"]["x"] == 1


def test_restarted_publisher_replaces_entry_pid_owned_unpublish():
    d = WorkerDirectory()
    d.publish_name("reg.own", {"pid": 0, "gen": 1})
    d.publish_name("reg.own", {"pid": 0, "gen": 2})  # restart re-publishes
    assert d.lookup_name("reg.own", timeout=1.0)["gen"] == 2
    # an unpublish from a pid that does not own the entry is a no-op
    assert not d.unpublish_name("reg.own", pid=999999)
    assert d.lookup_name("reg.own", timeout=1.0)["gen"] == 2


# -- observability ---------------------------------------------------------------


def test_publications_snapshot_and_pipetop_row():
    from repro.tools.pipetop import render

    d = WorkerDirectory()
    pub = publish("t.top", initial=make_paper_block(32, seed=8),
                  directory=d)
    sub = subscribe("t.top", directory=d, transport="shm")
    try:
        _drain(sub, 1)
        rows = publications_snapshot()
        mine = [r for r in rows if r["name"] == "t.top"]
        assert mine and mine[0]["head_epoch"] == 1
        assert mine[0]["subscribers"] == 1
        assert mine[0]["retained_bytes"] > 0
        frame = render({"subscriptions": rows})
        assert "subscriptions" in frame and "t.top" in frame
    finally:
        sub.close()
        pub.close()
    assert all(r["name"] != "t.top" for r in publications_snapshot())


def test_lag_gauges_update_and_drop_on_close():
    from repro.core import telemetry

    d = WorkerDirectory()
    pub = publish("t.lag", initial=make_paper_block(16, seed=9),
                  directory=d)
    sub = subscribe("t.lag", directory=d, transport="shm")
    try:
        _drain(sub, 1)
        snap = telemetry.registry().snapshot()["gauges"]
        assert any(k.startswith("pipe.subscription.lag_epochs")
                   and "pub=t.lag" in k for k in snap)
    finally:
        sub.close()
        pub.close()
    snap = telemetry.registry().snapshot()["gauges"]
    assert not any(k.startswith("pipe.subscription.lag_epochs")
                   and "pub=t.lag" in k for k in snap)


# -- plan verb -------------------------------------------------------------------


def test_plan_subscribe_verb_lifecycle():
    d = WorkerDirectory()
    src, dst1, dst2 = RowStore(), ColStore(), ColStore()
    base = make_paper_block(64, seed=11)
    src.put_block("feat", base)
    cp = (plan(directory=d)
          .subscribe(src, "feat", dst1, "feat_live")
          .subscribe(src, "feat", dst2, "feat_live")
          .compile())
    assert "subscription edge(s)" in cp.explain()
    with pytest.raises(PlanError):
        cp.execute()  # long-lived edges need start()
    handle = cp.start()
    try:
        assert handle.wait_caught_up(15.0), handle.watermarks
        assert_blocks_equal(dst1.get_block("feat_live"), base)
        # engine.append() drives delta capture -> epochs -> both targets
        delta = make_paper_block(16, seed=12)
        src.append("feat", delta)
        deadline = time.monotonic() + 15
        while (min(handle.watermarks.values()) < 2
               and time.monotonic() < deadline):
            handle.poll(timeout=0.2)
        got = dst1.get_block("feat_live")
        assert len(got) == len(base) + len(delta)
        assert_blocks_equal(dst2.get_block("feat_live"), got)
        # two shm subscribers share one broadcast conn: 2 epochs, 2 encodes
        pub = next(iter(handle.publications.values()))
        assert pub.stats.encodes == 2
        assert pub.subscribers == 2
    finally:
        handle.close()


def test_plan_subscribe_rejects_unknown_options_and_empty_source():
    d = WorkerDirectory()
    src, dst = RowStore(), ColStore()
    with pytest.raises(PlanError):
        plan(directory=d).subscribe(src, "t", dst, "t2", bogus=1)
    cp = plan(directory=d).subscribe(src, "missing", dst, "t2").compile()
    with pytest.raises(PlanError):
        cp.start()  # empty source table and no schema=


# -- serving path (flagship demo) ------------------------------------------------


def test_feature_view_serves_fresh_relation_without_reload():
    from repro.serve.engine import FeatureView

    d = WorkerDirectory()
    base = make_paper_block(64, seed=13)
    pub = publish("serve.features", initial=base, directory=d)
    sub = subscribe("serve.features", directory=d, transport="shm")
    view = FeatureView(sub)
    try:
        deadline = time.monotonic() + 15
        while view.epoch < 1 and time.monotonic() < deadline:
            view.refresh()
            time.sleep(0.02)
        assert view.epoch == 1
        assert_blocks_equal(view.block, base)
        pub.append(make_paper_block(8, seed=14))
        deadline = time.monotonic() + 15
        while view.epoch < 2 and time.monotonic() < deadline:
            view.refresh()
            time.sleep(0.02)
        assert view.epoch == 2 and len(view.block) == 72
        # publisher goes away: the view keeps serving its last image
        pub.close()
        deadline = time.monotonic() + 15
        while not view.ended and time.monotonic() < deadline:
            view.refresh()
            time.sleep(0.02)
        assert view.ended and len(view.block) == 72
        assert view.watermark == 2  # the resubscribe point
    finally:
        view.close()


def test_publisher_restart_subscriber_resubscribes_at_watermark():
    """The crash-heal loop, in-process: close + re-publish at the old
    head, subscriber resubscribes at its watermark, deltas continue with
    no snapshot and no gap."""
    d = WorkerDirectory()
    blocks = [make_paper_block(16, seed=30 + i) for i in range(4)]
    pub = publish("t.heal", schema=blocks[0].schema, directory=d)
    sub = subscribe("t.heal", directory=d, transport="shm")
    pub.append(blocks[0])
    pub.append(blocks[1])
    got = _drain(sub, 2)
    pub.close()
    with pytest.raises(PublicationEnded) as ei:
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            sub.poll(timeout=0.2)
    sub.close()
    wm = ei.value.watermark
    assert wm == 2
    # restart: same name, head continues where the old publisher stopped
    pub2 = publish("t.heal", schema=blocks[0].schema, directory=d,
                   start_epoch=wm)
    sub2 = subscribe("t.heal", directory=d, transport="shm", watermark=wm)
    try:
        pub2.append(blocks[2])
        pub2.append(blocks[3])
        got += _drain(sub2, 2)
        assert [e.epoch for e in got] == [1, 2, 3, 4]
        assert all(e.kind == "delta" for e in got)
        for e, b in zip(got, blocks):
            assert_blocks_equal(e.block, b)
        assert pub2.stats.snapshot_fallbacks == 0
    finally:
        sub2.close()
        pub2.close()
