"""Stream fabric: striped multi-stream pipes + N→M repartitioning shuffle.

Covers the reassembly protocol (ordering under adversarial per-stream
delays and cross-stream permutations, property-based where hypothesis is
available), the end-to-end striped pipe on all three transports, the
N=2→M=3 hash-partitioned shuffle across all five wire formats
(bit-identical modulo row order), directory hygiene (multi-endpoint
groups, dead-registrant GC), and the PipeStats merge/aggregation view.
"""

import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.datapipe import (
    DataPipeInput,
    DataPipeOutput,
    PipeConfig,
    PipeStats,
    collect_stats,
)
from repro.core.directory import Endpoint, WorkerDirectory, set_directory
from repro.core.fabric import (
    HashPartitioner,
    RangePartitioner,
    RoundRobinPartitioner,
    parse_partition,
    split_block,
)
from repro.core.session import transfer
from repro.core.stream import (
    FaninTransport,
    StripedReceiver,
    StripedSender,
    _hello_payload,
)
from repro.core.transport import (
    FRAME_BLOCK,
    FRAME_EOF,
    FRAME_SCHEMA,
    FRAME_STRIPE,
    Channel,
    ChannelTransport,
    LinkSim,
)
from repro.core.types import ColType, ColumnBlock, Schema
from repro.engines import make_engine
from repro.engines.base import make_paper_block

_SEQ = struct.Struct("<I")


# -- helpers -------------------------------------------------------------------------


def _bits(a: np.ndarray) -> np.ndarray:
    if a.dtype == np.float64:
        return a.view(np.uint64)
    if a.dtype == np.float32:
        return a.view(np.uint32)
    return a


def assert_same_rows(a: ColumnBlock, b: ColumnBlock) -> None:
    """Bit-identical as a *bag* of rows: sort both by the key column (a
    shuffle/parallel merge does not define a total row order)."""
    assert a.schema.types == b.schema.types
    assert len(a) == len(b)

    def _sorted_cols(blk):
        order = np.argsort(np.asarray(blk.columns[0]), kind="stable")
        out = []
        for f, c in zip(blk.schema, blk.columns):
            if f.type is ColType.STRING:
                out.append([c[i] for i in order])
            else:
                out.append(np.asarray(c)[order])
        return out

    for f, ca, cb in zip(a.schema, _sorted_cols(a), _sorted_cols(b)):
        if f.type is ColType.STRING:
            assert list(ca) == list(cb), f"column {f.name}"
        else:
            np.testing.assert_array_equal(
                _bits(np.asarray(ca, f.type.np_dtype)),
                _bits(np.asarray(cb, f.type.np_dtype)),
                err_msg=f"column {f.name}")


def _channel_pair(n):
    """N connected (sender-side, receiver-side) ChannelTransport members."""
    chans = [Channel() for _ in range(n)]
    tx = [ChannelTransport(c) for c in chans]
    rx = [ChannelTransport(c) for c in chans]
    return tx, rx


# -- reassembly protocol -------------------------------------------------------------


def test_striped_reassembly_deterministic_permutation():
    """Frames injected out of order *across* streams (in order within each,
    as TCP guarantees) must come out in global sequence order."""
    tx, rx = _channel_pair(3)
    payloads = [f"frame-{i}".encode() for i in range(12)]
    # stream assignment round-robin; deliver stream 2 entirely first, then
    # stream 1, then stream 0 — maximal cross-stream skew
    for s in (2, 1, 0):
        tx[s].send_frame(FRAME_STRIPE, _hello_payload(s, 3))
        for i in range(s, 12, 3):
            tx[s].send_frames(FRAME_BLOCK, (_SEQ.pack(i), payloads[i]))
        tx[s].send_frame(FRAME_EOF, b"")
    recv = StripedReceiver(rx, window=8)
    got = []
    while True:
        kind, payload = recv.recv_frame()
        if kind == FRAME_EOF:
            break
        got.append(bytes(payload))
    recv.close()
    assert got == payloads


def test_striped_reassembly_missing_frame_fails_loudly():
    tx, rx = _channel_pair(2)
    tx[0].send_frame(FRAME_STRIPE, _hello_payload(0, 2))
    tx[1].send_frame(FRAME_STRIPE, _hello_payload(1, 2))
    # seq 0 never sent; seq 1 arrives, then both streams end
    tx[1].send_frames(FRAME_BLOCK, (_SEQ.pack(1), b"orphan"))
    tx[0].send_frame(FRAME_EOF, b"")
    tx[1].send_frame(FRAME_EOF, b"")
    recv = StripedReceiver(rx, window=8)
    with pytest.raises(IOError, match="missing"):
        recv.recv_frame()
    recv.close()


def test_striped_hello_stream_count_mismatch_fails():
    tx, rx = _channel_pair(2)
    tx[0].send_frame(FRAME_STRIPE, _hello_payload(0, 5))  # claims 5 streams
    recv = StripedReceiver(rx, window=8)
    with pytest.raises(IOError, match="streams"):
        recv.recv_frame()
    recv.close()


@given(
    st.lists(st.binary(min_size=0, max_size=512), min_size=0, max_size=40),
    st.integers(1, 4),
    st.lists(st.floats(0, 0.002), min_size=4, max_size=4),
)
@settings(max_examples=20, deadline=None)
def test_striped_reassembly_property_random_delays(payloads, nstreams, delays):
    """Sender→receiver through N members with random per-stream latencies:
    the reassembled sequence must be byte-identical and in order."""
    chans = [Channel() for _ in range(nstreams)]
    tx = [ChannelTransport(c, LinkSim(latency_s=delays[i], min_sleep_s=0.0))
          for i, c in enumerate(chans)]
    rx = [ChannelTransport(c) for c in chans]
    sender = StripedSender(tx, depth=2)
    recv = StripedReceiver(rx, window=6)
    got = []
    err = []

    def consume():
        try:
            while True:
                kind, payload = recv.recv_frame()
                if kind == FRAME_EOF:
                    return
                got.append(bytes(payload))
        except BaseException as e:  # noqa: BLE001
            err.append(e)

    t = threading.Thread(target=consume)
    t.start()
    for p in payloads:
        sender.send_frames(FRAME_BLOCK, (p,))
    sender.send_frame(FRAME_EOF, b"")
    sender.close()
    t.join(30)
    assert not t.is_alive() and not err, err
    recv.close()
    assert got == payloads


# -- striped pipe end to end ---------------------------------------------------------


@pytest.mark.parametrize("transport", ["socket", "channel", "shm"])
def test_striped_pipe_roundtrip(transport):
    block = make_paper_block(4000, seed=3, strings=True)
    cfg = PipeConfig(mode="arrowcol", block_rows=256, streams=4,
                     transport=transport, shm_capacity=1 << 22)
    name = f"db://striped_{transport}?workers=1&query=q1"
    got = {}

    def imp():
        pipe = DataPipeInput(name, transport=transport, streams=4,
                             shm_capacity=1 << 22)
        got["blocks"] = list(pipe.blocks())
        pipe.close()
        got["stats"] = pipe.stats

    t = threading.Thread(target=imp)
    t.start()
    out = DataPipeOutput(name, config=cfg)
    out.write_block(block)
    out.close()
    t.join(30)
    assert not t.is_alive(), "striped importer hung"
    merged = ColumnBlock.concat(got["blocks"])
    assert_same_rows(block, merged)
    # every member stream carried frames, and both sides aggregated them
    assert len(out.stats.per_stream) == 4
    assert all(d["frames"] > 0 for d in out.stats.per_stream)
    assert len(got["stats"].per_stream) == 4
    assert sum(d["frames"] for d in got["stats"].per_stream) >= 16


def test_striped_pipe_text_mode_roundtrip():
    """Text-rung payloads must come out of reassembly as bytes (the reader
    calls .decode on them); regression for the memoryview leak."""
    set_directory(WorkerDirectory())
    name = "db://striped_text?workers=1&query=q1"
    got = {}

    def imp():
        pipe = DataPipeInput(name, transport="channel", streams=2)
        got["text"] = pipe.read()
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="text",
                                                 transport="channel",
                                                 streams=2))
    for i in range(50):
        out.write(f"{i},{i * 2}\n")
    out.close()
    t.join(30)
    assert not t.is_alive()
    assert got["text"] == "".join(f"{i},{i * 2}\n" for i in range(50))


def test_striped_transfer_through_engines():
    set_directory(WorkerDirectory())
    src = make_engine("colstore")
    dst = make_engine("colstore")
    block = make_paper_block(6000, seed=5)
    src.put_block("t", block)
    res = transfer(src, "t", dst, "t2",
                   config=PipeConfig(block_rows=512), streams=4, timeout=60)
    assert res.rows == 6000
    assert_same_rows(block, dst.get_block("t2"))
    assert res.export_stats is not None
    assert len(res.export_stats.per_stream) == 4
    assert res.export_stats.bytes_sent > 0


def test_striped_stub_eof_for_orphaned_importer():
    """Importers > exporters with striping: the orphan's whole member group
    gets stub EOFs and the importer sees a clean empty stream."""
    set_directory(WorkerDirectory())
    name = "db://stub_striped?workers=1&query=q1"
    results = {}

    def imp(i):
        pipe = DataPipeInput(name, streams=2, import_workers=2,
                             transport="channel")
        results[i] = sum(len(b) for b in pipe.blocks())
        pipe.close()

    threads = [threading.Thread(target=imp, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    out = DataPipeOutput(name, config=PipeConfig(transport="channel"))
    out.write_block(make_paper_block(100, seed=2))
    out.close()
    for t in threads:
        t.join(30)
    assert not any(t.is_alive() for t in threads), "orphan importer hung"
    assert sorted(results.values()) == [0, 100]


# -- N→M shuffle ---------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["parts", "binary_rows", "tagged",
                                  "arrowrow", "arrowcol"])
def test_shuffle_n2_m3_roundtrip_all_formats(mode):
    """The acceptance shuffle: N=2 exporters hash-partition into M=3
    importers, bit-identical as a bag, on every wire format."""
    set_directory(WorkerDirectory())
    src = make_engine("colstore")
    dst = make_engine("colstore")
    block = make_paper_block(1500, seed=11, strings=True)
    src.put_block("t", block)
    res = transfer(src, "t", dst, "t2",
                   config=PipeConfig(mode=mode, block_rows=256),
                   workers=2, import_workers=3, partition="hash", timeout=60)
    assert res.rows == 1500
    assert_same_rows(block, dst.get_block("t2"))
    assert res.export_stats is not None and res.import_stats is not None
    assert res.export_stats.rows == 1500


def test_shuffle_partitions_disjoint_and_consistent():
    """Each importer must hold exactly the keys that hash to it — the same
    placement the vectorized block path computes."""
    set_directory(WorkerDirectory())
    name_imp = "db://disjoint?workers=3&query=qd"
    name_exp = "db://disjoint?workers=1&query=qd"
    block = make_paper_block(900, seed=7)
    parts = {}

    def imp(i):
        pipe = DataPipeInput(name_imp, fanin=1, import_workers=3)
        blocks = list(pipe.blocks())
        pipe.close()
        parts[i] = (ColumnBlock.concat(blocks) if blocks
                    else ColumnBlock(Schema([]), []))

    threads = [threading.Thread(target=imp, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    from repro.core.fabric import ShuffleWriter

    w = ShuffleWriter(name_exp, config=PipeConfig(partition="hash",
                                                  block_rows=128))
    w.write_block(block)
    w.close()
    for t in threads:
        t.join(30)
    assert not any(t.is_alive() for t in threads)
    want = HashPartitioner(0).indices(block, 3)
    total = 0
    # importer registration order is not the directory entry order, so
    # match partitions by content: every received key set must equal one
    # predicted partition exactly
    want_sets = [set(np.asarray(block.columns[0])[want == p].tolist())
                 for p in range(3)]
    got_sets = [set(np.asarray(b.columns[0]).tolist()) if len(b) else set()
                for b in parts.values()]
    total = sum(len(s) for s in got_sets)
    assert total == 900
    assert sorted(map(sorted, want_sets)) == sorted(map(sorted, got_sets))


def test_shuffle_rejects_shared_shm_endpoint():
    """streams×partition and shm shuffles compose via *slotted* endpoints
    now; the remaining invariant is that a hand-wired SHARED shm ring (one
    segment, multiple producers) is still refused — the ring is SPSC."""
    d = WorkerDirectory()
    set_directory(d)
    d.register("x", Endpoint(shm_name="bogus-ring", shm_capacity=1 << 16,
                             shared=True), "1", import_workers=1)
    from repro.core.fabric import ShuffleWriter

    with pytest.raises(ValueError, match="single-producer"):
        ShuffleWriter("db://x?workers=1&query=1",
                      config=PipeConfig(partition="hash", transport="shm",
                                        connect_timeout=5.0))


def test_striped_shuffle_channel_roundtrip():
    """streams=2 × hash partition over the in-process channel: slotted
    member pipes, each striped across 2 channels."""
    set_directory(WorkerDirectory())
    src, dst = make_engine("colstore"), make_engine("colstore")
    blk = make_paper_block(1500, seed=21)
    src.put_block("t", blk)
    r = transfer(src, "t", dst, "t2",
                 config=PipeConfig(mode="arrowcol", block_rows=128),
                 workers=2, import_workers=3, partition="hash:key",
                 streams=2, transport="channel", timeout=60)
    assert r.rows == 1500
    assert_same_rows(blk, dst.get_block("t2"))


# -- partitioners --------------------------------------------------------------------


def test_hash_partitioner_vector_scalar_consistency():
    block = make_paper_block(500, seed=9, strings=True)
    for key in (0, "key", 2, 4):  # int64, named int64, float64/str columns
        p = HashPartitioner(key)
        idx = p.indices(block, 5)
        k = key if isinstance(key, int) else block.schema.index_of(key)
        col = block.columns[k]
        for r in range(0, 500, 37):
            v = col[r] if isinstance(col, list) else col[r].item()
            assert p.part_of_row(v, 5) == idx[r], (key, r)


def test_round_robin_partitioner_cycles_across_blocks():
    p = RoundRobinPartitioner()
    b1 = make_paper_block(5, seed=1)
    i1 = p.indices(b1, 3)
    i2 = p.indices(b1, 3)
    np.testing.assert_array_equal(i1, [0, 1, 2, 0, 1])
    np.testing.assert_array_equal(i2, [2, 0, 1, 2, 0])


def test_range_partitioner_orders_partitions():
    p = RangePartitioner(0)
    block = make_paper_block(1000, seed=3)
    idx = p.indices(block, 4)
    key = np.asarray(block.columns[0])
    assert set(idx.tolist()) == {0, 1, 2, 3}
    # ranges must be ordered: every key in partition p < every key in p+1
    for a in range(3):
        assert key[idx == a].max() <= key[idx == a + 1].min()


def test_parse_partition_specs():
    assert isinstance(parse_partition("hash"), HashPartitioner)
    assert parse_partition("hash:key").key == "key"
    assert parse_partition("hash:3").key == 3
    assert isinstance(parse_partition("rr"), RoundRobinPartitioner)
    assert isinstance(parse_partition("range:1"), RangePartitioner)
    with pytest.raises(ValueError):
        parse_partition("modulo")


def test_split_block_partitions_all_rows():
    block = make_paper_block(300, seed=2, strings=True)
    idx = HashPartitioner(0).indices(block, 4)
    subs = split_block(block, idx, 4)
    assert sum(len(s) for s in subs) == 300
    assert_same_rows(block, ColumnBlock.concat([s for s in subs if len(s)]))


# -- fan-in merge --------------------------------------------------------------------


def test_fanin_dedupes_schema_and_counts_sources():
    ch = Channel()
    tx1, tx2 = ChannelTransport(ch, owns_channel=False), \
        ChannelTransport(ch, owns_channel=False)
    fan = FaninTransport([ChannelTransport(ch)], expected_sources=2)
    tx1.send_frame(FRAME_SCHEMA, b"{}")
    tx1.send_frame(FRAME_BLOCK, b"a")
    tx1.send_frame(FRAME_EOF, b"")
    tx2.send_frame(FRAME_SCHEMA, b"{}")
    tx2.send_frame(FRAME_BLOCK, b"b")
    tx2.send_frame(FRAME_EOF, b"")
    kinds = []
    while True:
        kind, payload = fan.recv_frame()
        kinds.append(kind)
        if kind == FRAME_EOF:
            break
    fan.close()
    assert kinds.count(FRAME_SCHEMA) == 1  # duplicate dropped
    assert kinds.count(FRAME_BLOCK) == 2
    assert kinds[-1] == FRAME_EOF
    # EOF only after BOTH sources finished
    assert kinds.index(FRAME_EOF) == len(kinds) - 1


def test_fanin_rejects_mixed_relations():
    """Sources describing different relations must fail the merge loudly,
    not decode one source's blocks under the other's layout."""
    from repro.core.types import Field
    from repro.core.wire import encode_schema

    ch = Channel()
    tx1 = ChannelTransport(ch, owns_channel=False)
    tx2 = ChannelTransport(ch, owns_channel=False)
    fan = FaninTransport([ChannelTransport(ch)], expected_sources=2)
    s_int = encode_schema(Schema([Field("a", ColType.INT64)]), {})
    s_flt = encode_schema(Schema([Field("a", ColType.FLOAT64)]), {})
    tx1.send_frame(FRAME_SCHEMA, s_int)
    tx2.send_frame(FRAME_SCHEMA, s_flt)
    assert fan.recv_frame()[0] == FRAME_SCHEMA
    with pytest.raises(IOError, match="disagree"):
        fan.recv_frame()
    fan.close()


def test_fanin_tolerates_dialect_only_schema_differences():
    """Same column types, different meta (per-source sniffed delimiter):
    the duplicate is dropped, the stream continues."""
    from repro.core.types import Field
    from repro.core.wire import encode_schema

    ch = Channel()
    tx1 = ChannelTransport(ch, owns_channel=False)
    tx2 = ChannelTransport(ch, owns_channel=False)
    fan = FaninTransport([ChannelTransport(ch)], expected_sources=2)
    schema = Schema([Field("a", ColType.INT64)])
    tx1.send_frame(FRAME_SCHEMA, encode_schema(schema, {"delimiter": ","}))
    tx2.send_frame(FRAME_SCHEMA, encode_schema(schema, {"delimiter": "\t"}))
    tx1.send_frame(FRAME_EOF, b"")
    tx2.send_frame(FRAME_EOF, b"")
    kinds = []
    while True:
        kind, _ = fan.recv_frame()
        kinds.append(kind)
        if kind == FRAME_EOF:
            break
    fan.close()
    assert kinds == [FRAME_SCHEMA, FRAME_EOF]


# -- directory hygiene ---------------------------------------------------------------


def _dead_pid() -> int:
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()
    return proc.pid


def test_directory_gc_skips_dead_registrants_on_query():
    d = WorkerDirectory()
    ch = Channel()
    d.register("ds", Endpoint(pid=_dead_pid(), host="127.0.0.1", port=1),
               "q")
    d.register("ds", Endpoint(channel=ch), "q")
    ep = d.query("ds", "q", timeout=5.0)
    assert ep.is_channel  # the dead registrant's endpoint was skipped
    with pytest.raises(TimeoutError):
        d.query("ds", "q", timeout=0.1)  # and it is gone, not requeued


def test_directory_reset_unlinks_dead_shm_endpoints():
    from multiprocessing import shared_memory

    from repro.core.shm_ring import ShmRing

    ring = ShmRing.create(capacity=1 << 16, role="reader")
    name = ring.name
    d = WorkerDirectory()
    d.register("leak", Endpoint(shm_name=name, shm_capacity=1 << 16,
                                pid=_dead_pid()))
    d.reset("leak")
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name, create=False)
    ring.close()  # release this process's mapping (unlink already done)


def test_directory_group_registration_pops_whole_group():
    d = WorkerDirectory()
    members = tuple(Endpoint("127.0.0.1", 1000 + i) for i in range(3))
    d.register("g", Endpoint(members=members), "q")
    ep = d.query("g", "q", timeout=5.0)
    assert ep.is_group and len(ep.members) == 3
    assert ep.pid > 0  # stamped by the directory


def test_directory_query_all_waits_for_declared_importers():
    d = WorkerDirectory()
    got = {}

    def ask():
        got["eps"] = d.query_all("shuf", "q", timeout=10.0)

    t = threading.Thread(target=ask)
    t.start()
    time.sleep(0.05)
    d.register("shuf", Endpoint("h", 1), "q", import_workers=2)
    time.sleep(0.05)
    assert t.is_alive()  # one of two registered: still waiting
    d.register("shuf", Endpoint("h", 2), "q", import_workers=2)
    t.join(10)
    assert not t.is_alive()
    assert {e.port for e in got["eps"]} == {1, 2}
    # not popped: a second exporter sees the same set
    assert {e.port for e in d.query_all("shuf", "q", timeout=1.0)} == {1, 2}


# -- stats ---------------------------------------------------------------------------


def test_pipestats_merge_sums_and_concatenates():
    a = PipeStats(bytes_sent=10, frames_sent=2, rows=5, blocks=1,
                  send_overlap_s=0.5, per_stream=[{"stream": 0}])
    b = PipeStats(bytes_sent=7, frames_sent=1, rows=3, blocks=1,
                  decode_pool_hits=4, per_stream=[{"stream": 1}])
    merged = PipeStats().merge(a).merge(b)
    assert merged.bytes_sent == 17 and merged.frames_sent == 3
    assert merged.rows == 8 and merged.blocks == 2
    assert merged.send_overlap_s == pytest.approx(0.5)
    assert merged.decode_pool_hits == 4
    assert merged.per_stream == [{"stream": 0}, {"stream": 1}]
    # merge mutates only the aggregate
    assert a.bytes_sent == 10 and b.bytes_sent == 7


def test_transfer_result_carries_merged_stats():
    set_directory(WorkerDirectory())
    src = make_engine("colstore")
    dst = make_engine("colstore")
    src.put_block("t", make_paper_block(2000, seed=4))
    res = transfer(src, "t", dst, "t2",
                   config=PipeConfig(block_rows=256), timeout=60)
    assert res.export_stats is not None and res.import_stats is not None
    assert res.bytes_moved == res.export_stats.bytes_sent > 0
    assert res.export_stats.rows == 2000
    # the sink was drained: a second collect finds nothing
    assert collect_stats("colstore2colstore", "nope") == {}


# -- CI smoke (streams=4 + N=2→M=3 in one quick pass) --------------------------------


def test_multistream_smoke():
    set_directory(WorkerDirectory())
    src = make_engine("colstore")
    dst = make_engine("colstore")
    block = make_paper_block(2000, seed=1)
    src.put_block("t", block)
    res = transfer(src, "t", dst, "t2",
                   config=PipeConfig(block_rows=256), streams=4, timeout=60)
    assert res.rows == 2000 and len(res.export_stats.per_stream) == 4
    set_directory(WorkerDirectory())
    src.put_block("t", block)
    dst.drop("t2")
    res = transfer(src, "t", dst, "t2",
                   config=PipeConfig(block_rows=256),
                   workers=2, import_workers=3, partition="hash", timeout=60)
    assert res.rows == 2000
    assert_same_rows(block, dst.get_block("t2"))
