"""Telemetry: span tracer (and its near-free disabled path), trace
context propagation across threads AND processes, Chrome-trace export,
the metrics registry, the per-attempt stats sink (eviction order at
``_SINK_MAX``), the flight recorder + ``attach_flight``, the broker
``stats`` RPC, and the ``pipetop`` renderer."""

import json
import multiprocessing
import os
import threading
import time

import pytest

from repro.core import telemetry
from repro.core.datapipe import (
    DataPipeInput,
    DataPipeOutput,
    PipeConfig,
    PipeStats,
    collect_stats,
    collect_stats_by_attempt,
)
from repro.core.datapipe import _SINK_MAX, _record_stats, parse_reserved
from repro.core.telemetry import (
    DEFAULT_BUCKETS,
    FlightRecorder,
    MetricsRegistry,
    attach_flight,
    chrome_trace,
    merge_trace_dir,
    span,
)
from repro.engines import make_engine, make_paper_block

_mp = multiprocessing.get_context("spawn")
JOIN_S = 60


@pytest.fixture(autouse=True)
def _tracing_off():
    """Tests own the tracer's lifecycle; never leak it across tests."""
    telemetry.disable_tracing()
    yield
    telemetry.disable_tracing()


# -- the disabled path --------------------------------------------------------------


def test_disabled_span_is_shared_null_singleton():
    """The off path's contract: no tracer -> span() returns ONE
    preallocated no-op object (no allocation, no clock read)."""
    assert not telemetry.tracing_enabled()
    a = span("export.encode", rows=100)
    b = span("import.decode")
    assert a is b is telemetry._NULL_SPAN
    with a as s:
        s.set(anything="ignored")  # no-op, never raises
    assert telemetry.current_ctx() == ""
    assert telemetry.tracer() is None


def test_disabled_pipes_record_nothing():
    """A full transfer with tracing off must leave the tracer untouched
    (the <2% fig11.telemetry_overhead rung measures the wall-clock side
    of this; the structural side is asserted here)."""
    block = make_paper_block(64, seed=2)
    name = "db://toff?query=1"
    got = {}

    def imp():
        pipe = DataPipeInput(name)
        got["rows"] = sum(len(b) for b in pipe.blocks())
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    _pump(name, block, PipeConfig(mode="arrowcol", block_rows=32))
    t.join(20)
    assert got["rows"] == 64
    assert telemetry.tracer() is None  # nothing silently enabled it


# -- live tracer --------------------------------------------------------------------


def _pump(name, block, config):
    from repro.core.astring import AString

    out = DataPipeOutput(name, config=config)
    for row in block.to_rows().rows:
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append(",")
            parts.append(v)
        parts.append("\n")
        out.write(AString(parts))
    out.close()


def test_nested_spans_share_trace_and_parent():
    tr = telemetry.enable_tracing()
    with span("outer", layer=1):
        outer_ctx = telemetry.current_ctx()
        with span("inner"):
            pass
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner"}
    assert spans["inner"].trace_id == spans["outer"].trace_id
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert outer_ctx == (f"{spans['outer'].trace_id}:"
                        f"{spans['outer'].span_id}")
    assert spans["outer"].duration >= spans["inner"].duration >= 0
    assert spans["outer"].attrs == {"layer": 1}


def test_trace_context_adopts_foreign_ctx_on_worker_thread():
    """plan worker threads re-adopt the spawning thread's context."""
    tr = telemetry.enable_tracing()
    ctx = telemetry.new_trace_ctx()

    def work():
        with telemetry.trace_context(ctx), span("unit"):
            pass

    t = threading.Thread(target=work)
    t.start()
    t.join(10)
    (s,) = tr.spans()
    tid, sid = telemetry.split_ctx(ctx)
    assert s.trace_id == tid and s.parent_id == sid


def test_tracer_ring_is_bounded_and_counts_drops():
    tr = telemetry.enable_tracing(capacity=8)
    for i in range(12):
        with span(f"s{i}"):
            pass
    assert len(tr.spans()) == 8
    assert tr.dropped == 4
    assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(4, 12)]


def test_chrome_trace_export_roundtrips(tmp_path):
    telemetry.enable_tracing()
    with span("export.encode", frames=3):
        pass
    doc = chrome_trace()
    (ev,) = doc["traceEvents"]
    assert ev["ph"] == "X" and ev["name"] == "export.encode"
    assert ev["dur"] >= 0 and ev["args"]["frames"] == 3
    p = telemetry.dump_chrome_trace(str(tmp_path / "trace.json"))
    loaded = json.loads(open(p).read())
    assert loaded["traceEvents"][0]["name"] == "export.encode"


def test_traced_transfer_single_trace_in_process():
    """Exporter and importer threads of one pipe land in ONE trace, with
    the lifecycle spans parented under the per-side pipe spans."""
    tr = telemetry.enable_tracing()
    block = make_paper_block(64, seed=3)
    name = "db://ttrace?query=1"

    def imp():
        pipe = DataPipeInput(name, trace=True)
        list(pipe.blocks())
        pipe.close()

    t = threading.Thread(target=imp)
    t.start()
    _pump(name, block, PipeConfig(mode="arrowcol", block_rows=32,
                                  trace=True))
    t.join(20)
    spans = tr.spans()
    names = {s.name for s in spans}
    assert {"export.pipe", "import.pipe", "export.rendezvous",
            "import.rendezvous", "export.send", "import.wait_schema",
            "import.wait", "import.decode"} <= names
    assert len({s.trace_id for s in spans}) == 1  # ONE trace
    by_name = {s.name: s for s in spans}
    assert by_name["export.pipe"].attrs["rows"] == 64
    # the importer's pipe span parents to the exporter's via the hello
    # (or vice versa via the registration) — either way, linked
    assert by_name["export.rendezvous"].parent_id == \
        by_name["export.pipe"].span_id


# -- cross-process propagation -------------------------------------------------------


def _child_export(host, port, name, n_rows):
    from repro.core.directory import DirectoryClient, set_directory

    set_directory(DirectoryClient(host, port))
    block = make_paper_block(n_rows, seed=9)
    _pump(name, block, PipeConfig(mode="arrowcol", block_rows=32,
                                  trace=True))


def _child_import(host, port, name, transport):
    from repro.core.directory import DirectoryClient, set_directory

    set_directory(DirectoryClient(host, port))
    pipe = DataPipeInput(name, transport=transport, trace=True)
    n = sum(len(b) for b in pipe.blocks())
    pipe.close()
    assert n == 96, n


@pytest.mark.parametrize("transport", ["socket", "shm"])
def test_cross_process_transfer_yields_single_trace(tmp_path, transport):
    """The acceptance scenario: exporter and importer in SEPARATE
    processes, trace context propagated through the directory
    registration / schema hello, spans spilled per-process via
    PIPEGEN_TRACE_DIR — merged, they form one trace with both sides."""
    from repro.core.directory import DirectoryServer

    spill = str(tmp_path / "spans")
    name = "db://xproc?query=1"
    server = DirectoryServer().start()
    os.environ["PIPEGEN_TRACE"] = "1"
    os.environ["PIPEGEN_TRACE_DIR"] = spill
    try:
        pi = _mp.Process(target=_child_import,
                         args=(server.host, server.port, name, transport))
        pe = _mp.Process(target=_child_export,
                         args=(server.host, server.port, name, 96))
        pi.start()
        pe.start()
        pi.join(JOIN_S)
        pe.join(JOIN_S)
        assert pi.exitcode == 0 and pe.exitcode == 0
    finally:
        del os.environ["PIPEGEN_TRACE"]
        del os.environ["PIPEGEN_TRACE_DIR"]
        server.stop()
    spans = merge_trace_dir(spill)
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, s)
    assert "export.pipe" in by_name and "import.pipe" in by_name
    exp, imp = by_name["export.pipe"], by_name["import.pipe"]
    assert exp.pid != imp.pid  # genuinely two processes
    assert exp.trace_id == imp.trace_id  # ONE trace across the pipe
    assert len({s.trace_id for s in spans}) == 1
    # exportable as one Chrome-trace document
    doc = chrome_trace(spans)
    assert len(doc["traceEvents"]) == len(spans) >= 4


# -- metrics registry ---------------------------------------------------------------


def test_counters_gauges_and_labels_are_get_or_create():
    reg = MetricsRegistry()
    reg.counter("pipe.bytes", role="export").inc(100)
    reg.counter("pipe.bytes", role="export").inc(28)
    reg.counter("pipe.bytes", role="import").inc(5)
    assert reg.counter("pipe.bytes", role="export").value == 128
    reg.gauge("queue_depth").set(7)
    reg.gauge("queue_depth").add(-2)
    snap = reg.snapshot()
    assert snap["counters"]["pipe.bytes{role=export}"] == 128
    assert snap["counters"]["pipe.bytes{role=import}"] == 5
    assert snap["gauges"]["queue_depth"] == 5
    json.dumps(snap)  # must be JSON-serializable verbatim


def test_histogram_buckets_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("wait_s")
    assert h.bounds == DEFAULT_BUCKETS
    for v in (0.0002, 0.0002, 0.0002, 0.0002, 0.0002, 0.0002, 0.0002,
              0.0002, 0.05, 200.0):
        h.observe(v)
    assert h.total == 10 and h.sum == pytest.approx(0.0016 + 0.05 + 200)
    assert h.quantile(0.5) == 4e-4  # upper bound of the 200us bucket
    assert h.quantile(0.95) == float("inf")  # the 200s outlier
    snap = reg.snapshot()["histograms"]["wait_s"]
    assert snap["total"] == 10 and snap["buckets"]["+Inf"] == 1


# -- per-attempt stats sink ---------------------------------------------------------


def _stats(n=1):
    st = PipeStats()
    st.bytes_sent = 10 * n
    st.frames_sent = n
    return st


def test_stats_sink_folds_attempts_and_peeks_per_attempt():
    rn = parse_reserved("db://attr?query=qa")
    _record_stats(rn, "export", _stats(1), attempt=0)
    _record_stats(rn, "export", _stats(2), attempt=1)
    _record_stats(rn, "import", _stats(5), attempt=1)
    # non-destructive per-attempt view first
    by = collect_stats_by_attempt("attr", "qa")
    assert set(by["export"]) == {0, 1}
    assert by["export"][0].bytes_sent == 10
    assert by["export"][1].bytes_sent == 20
    assert set(by["import"]) == {1}
    # the folded view pops and merges across attempts
    folded = collect_stats("attr", "qa")
    assert folded["export"].bytes_sent == 30
    assert folded["export"].frames_sent == 3
    assert folded["import"].bytes_sent == 50
    assert collect_stats("attr", "qa") == {}  # popped


def test_stats_sink_evicts_oldest_insertion_at_cap():
    """Fill the sink past _SINK_MAX and assert FIFO eviction: the oldest
    key is gone (collect returns empty), the newest are intact, and
    re-recording an EXISTING key never evicts."""
    base = f"evt{os.getpid()}"
    for i in range(_SINK_MAX + 3):
        rn = parse_reserved(f"db://{base}{i}?query=e")
        _record_stats(rn, "export", _stats(i + 1))
    # the three oldest fell off the front, in insertion order
    for i in range(3):
        assert collect_stats(f"{base}{i}", "e") == {}
    # merging into a surviving key must NOT evict anything
    rn = parse_reserved(f"db://{base}3?query=e")
    _record_stats(rn, "export", _stats(1))
    assert collect_stats(f"{base}4", "e")["export"].frames_sent == 5
    got = collect_stats(f"{base}3", "e")
    assert got["export"].frames_sent == 4 + 1  # merged, not replaced
    for i in range(5, _SINK_MAX + 3):
        assert collect_stats(f"{base}{i}", "e")["export"] is not None


# -- flight recorder ----------------------------------------------------------------


def test_flight_recorder_ring_and_render():
    fr = FlightRecorder(depth=4, name="edge e1")
    for i in range(6):
        fr.note("frame", seq=i)
    assert len(fr) == 4
    assert [kv["seq"] for _, _, kv in fr.events()] == [2, 3, 4, 5]
    text = fr.render()
    assert "flight recorder [edge e1]" in text
    assert "seq=5" in text and "seq=0" not in text
    assert FlightRecorder().render() == "(flight recorder empty)"


def test_attach_flight_staples_timeline_and_is_idempotent():
    fr = FlightRecorder(name="edge e2")
    fr.note("import.open", dataset="t")
    fr.note("import.lease_lost")
    e = BrokenPipeError("lease lost")
    assert attach_flight(e, fr) is e
    assert "import.lease_lost" in e.flight_timeline
    assert "import.lease_lost" in str(e)  # visible in a bare traceback
    first = str(e)
    attach_flight(e, fr)  # second staple is a no-op
    assert str(e) == first
    # empty recorders attach nothing (clear the global fault recorder
    # too — attach_flight auto-includes it when non-empty, and earlier
    # suites may have fed it)
    telemetry.fault_recorder.clear()
    e2 = ValueError("x")
    attach_flight(e2, FlightRecorder())
    assert getattr(e2, "flight_timeline", None) is None


def test_attach_flight_appends_dump_file(tmp_path, monkeypatch):
    dump = tmp_path / "flight.txt"
    monkeypatch.setenv("PIPEGEN_FLIGHT_DUMP", str(dump))
    fr = FlightRecorder(name="edge e3")
    fr.note("export.open")
    attach_flight(OSError("boom"), fr)
    assert dump.exists() and "export.open" in dump.read_text()


def test_raised_pipe_error_carries_flight_timeline():
    """A real failure path: the importer's lease is lost (its renewals
    stop landing — the registration was GC'd) before any exporter shows
    up; the raised error arrives with the recorder timeline stapled."""
    from repro.core.directory import WorkerDirectory, set_directory

    d = WorkerDirectory(lease_ttl=0.2)
    d.renew = lambda *a, **k: 0  # every renewal finds the entry gone
    set_directory(d)
    pipe = DataPipeInput("db://flt?workers=1&query=f1",
                         transport="channel", lease_s=0.2)
    try:
        assert pipe._lease_lost.wait(10)
        with pytest.raises(BrokenPipeError) as ei:
            pipe.read()
        assert "flight recorder" in str(ei.value)
        assert "import.open" in ei.value.flight_timeline
        assert "import.lease_lost" in ei.value.flight_timeline
    finally:
        pipe.close()


# -- broker stats RPC + pipetop -----------------------------------------------------


def test_broker_stats_rpc_and_pipetop_render():
    from repro.core.broker import PipeBroker
    from repro.core.directory import DirectoryClient
    from repro.tools.pipetop import render

    broker = PipeBroker(serve=True, max_rings=8, lease_ttl=None,
                        hub=True).start()
    try:
        with broker.admit(tenant="acme", qos="latency", rings=2,
                          segments=2, nbytes=1 << 20):
            stats = DirectoryClient(broker.host, broker.port).stats()
        assert stats["admitted"] >= 1
        assert stats["active_by_tenant"] == {} or "acme" in str(stats)
        assert stats["grants_by"].get("acme/latency", 0) >= 1
        assert "grant_wait" in stats and stats["grant_wait"]["total"] >= 1
        assert "metrics" in stats and "counters" in stats["metrics"]
        json.dumps(stats)  # the RPC really is JSON end-to-end
        text = render(stats, now=time.time())
        assert "admitted=" in text and "acme" in text
        assert "grant wait" in text and "doorbells" in text
    finally:
        broker.stop()


def test_pipetop_renders_canned_snapshot_without_broker():
    from repro.tools.pipetop import render

    text = render({
        "admitted": 3, "queued": 1, "rejected": 2, "waiting": 4,
        "active_rings": 2, "active_segments": 2,
        "active_bytes": 3 * (1 << 20), "fds": 37,
        "active_by_qos": {"latency": 1, "bulk": 1},
        "active_by_tenant": {"acme": [2, 2, 3 * (1 << 20)]},
        "grants_by": {"acme/latency": 3},
        "rejects_by": {"acme/bulk": 2},
        "grant_wait": {"total": 3, "sum_s": 0.01, "p50_s": 0.0004,
                       "p95_s": 0.0016, "p99_s": 0.0016},
        "hub_registered": 2, "hub_wakeups": 40, "hub_waits": 41,
        "pool": {"spsc_parked": 1, "broadcast_parked": 0},
        "buffer_pool": {"hits": 10, "misses": 2, "bytes_retained": 4096},
    })
    assert "queue_depth=4" in text
    assert "acme" in text and "latency=3" in text and "bulk=2" in text
    assert "registered=2" in text
    assert "hit/miss=10/2" in text
    # empty snapshot must not crash either
    assert "no tenants yet" in render({})


def test_pipetop_cli_once_against_live_broker(capsys):
    from repro.core.broker import PipeBroker
    from repro.tools.pipetop import main as pipetop_main

    broker = PipeBroker(serve=True, lease_ttl=None).start()
    try:
        rc = pipetop_main(["--port", str(broker.port), "--once"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "pipetop" in out and "admission" in out
    finally:
        broker.stop()
