"""Transport edge cases: partial reads, empty frames, vectored sends,
deficit-based link accounting, pipelined-sender error propagation."""

import socket
import struct
import threading
import time

import numpy as np
import pytest

import repro.core.transport as transport_mod
from repro.core.astring import AString
from repro.core.datapipe import DataPipeInput, DataPipeOutput, PipeConfig
from repro.core.iobuf import SegmentList
from repro.core.transport import (
    FRAME_BLOCK,
    FRAME_EOF,
    FRAME_TEXT,
    Channel,
    ChannelTransport,
    LinkSim,
    SocketTransport,
    listen_socket,
)
from repro.engines.base import make_paper_block


def _tcp_pair():
    ls = listen_socket()
    h, p = ls.getsockname()
    c = socket.create_connection((h, p))
    s, _ = ls.accept()
    ls.close()
    return c, s


# -- partial / truncated streams ---------------------------------------------------

def test_recv_frame_short_header_is_eof():
    c, s = _tcp_pair()
    rx = SocketTransport(s)
    c.sendall(b"B\x01")  # 2 of 5 header bytes, then FIN
    c.close()
    kind, payload = rx.recv_frame()
    assert kind == FRAME_EOF and payload == b""
    rx.close()


def test_recv_frame_truncated_payload_is_eof():
    c, s = _tcp_pair()
    rx = SocketTransport(s)
    hdr = struct.Struct("<cI").pack(FRAME_BLOCK, 100)
    c.sendall(hdr + b"only-ten-b")  # 10 of 100 payload bytes, then FIN
    c.close()
    kind, payload = rx.recv_frame()
    assert kind == FRAME_EOF and payload == b""
    rx.close()


def test_zero_length_payload_frame_roundtrip():
    c, s = _tcp_pair()
    tx, rx = SocketTransport(c), SocketTransport(s)
    tx.send_frame(FRAME_TEXT, b"")
    tx.send_frame(FRAME_EOF, b"")
    assert rx.recv_frame() == (FRAME_TEXT, b"")
    assert rx.recv_frame() == (FRAME_EOF, b"")
    tx.close()
    rx.close()


# -- vectored scatter-gather send --------------------------------------------------

def test_send_frames_vectored_roundtrip_mixed_views():
    c, s = _tcp_pair()
    tx, rx = SocketTransport(c), SocketTransport(s)
    arr = np.arange(100, dtype=np.int64)
    segs = [b"head", memoryview(b"-mid-"), bytearray(b"tail"), arr.data]
    want = b"head-mid-tail" + arr.tobytes()
    tx.send_frames(FRAME_BLOCK, segs)
    kind, payload = rx.recv_frame()
    assert kind == FRAME_BLOCK and payload == want
    assert tx.bytes_sent == len(want) + 5  # header charged too
    assert tx.frames_sent == 1
    tx.close()
    rx.close()


def test_send_frames_many_segments_exceed_iov_max():
    c, s = _tcp_pair()
    tx, rx = SocketTransport(c), SocketTransport(s)
    segs = [bytes([i % 251]) * 3 for i in range(2000)]  # >> _IOV_MAX iovecs
    want = b"".join(segs)

    got = {}

    def recv():
        got["frame"] = rx.recv_frame()

    t = threading.Thread(target=recv)
    t.start()
    tx.send_frames(FRAME_BLOCK, segs)
    t.join(10)
    assert got["frame"] == (FRAME_BLOCK, want)
    tx.close()
    rx.close()


def test_send_frames_skips_empty_segments():
    c, s = _tcp_pair()
    tx, rx = SocketTransport(c), SocketTransport(s)
    tx.send_frames(FRAME_TEXT, [b"", b"ab", memoryview(b""), b"cd", b""])
    assert rx.recv_frame() == (FRAME_TEXT, b"abcd")
    tx.close()
    rx.close()


# -- simulated link accounting -----------------------------------------------------

def test_link_charges_header_bytes_on_both_transports():
    """SocketTransport and ChannelTransport must account identically."""
    payload = b"x" * 1000
    ch = Channel()
    ct = ChannelTransport(ch)
    ct.send_frame(FRAME_TEXT, payload)
    c, s = _tcp_pair()
    st = SocketTransport(c)
    st.send_frame(FRAME_TEXT, payload)
    assert ct.bytes_sent == st.bytes_sent == len(payload) + 5
    st.close()
    s.close()


def test_link_sim_deficit_coalesces_small_frames(monkeypatch):
    """Many small frames accumulate owed delay and sleep in few batches
    instead of once per frame (no per-frame oversleep)."""
    sleeps = []
    real_sleep = time.sleep

    def recording_sleep(d):
        sleeps.append(d)
        real_sleep(d)

    monkeypatch.setattr(transport_mod.time, "sleep", recording_sleep)
    ch = Channel(maxsize=200)
    link = LinkSim(latency_s=0.0004, min_sleep_s=0.002)
    tx = ChannelTransport(ch, link)
    for _ in range(20):  # 20 * 0.4ms = 8ms owed in total
        tx.send_frame(FRAME_TEXT, b"tiny")
    # coalesced: only every ~5th frame crosses the 2 ms threshold (the seed
    # slept once per frame); oversleep credit can only reduce the count
    assert 1 <= len(sleeps) <= 6
    # requested sleep time never exceeds what the link model owes (+ one
    # threshold of slack for the final pending batch)
    assert sum(sleeps) <= 20 * 0.0004 + link.min_sleep_s


def test_link_sim_oversleep_credited_back():
    """A measured oversleep becomes negative debt absorbed by later sends."""
    link = LinkSim(latency_s=0.001, min_sleep_s=0.002)
    ch = Channel(maxsize=200)
    tx = ChannelTransport(ch, link)
    t0 = time.perf_counter()
    for _ in range(10):  # 10 ms owed
        tx.send_frame(FRAME_TEXT, b"p")
    elapsed = time.perf_counter() - t0
    # owed 10 ms; allow generous scheduler slack but catch the seed
    # behavior of 10 independent sleeps each overshooting by a quantum
    assert elapsed < 0.1


def test_channel_close_unblocks_reader():
    ch = Channel()
    tx = ChannelTransport(ch)
    rx = ChannelTransport(ch)
    got = {}

    def recv():
        got["frame"] = rx.recv_frame()

    t = threading.Thread(target=recv, daemon=True)
    t.start()
    tx.close()  # no EOF frame was ever sent
    t.join(5)
    assert not t.is_alive()
    assert got["frame"] == (FRAME_EOF, b"")


# -- pipelined sender error propagation --------------------------------------------

class _BoomError(RuntimeError):
    pass


def _pump_rows(out, block):
    rb = block.to_rows()
    for row in rb.rows:
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append(",")
            parts.append(v)
        parts.append("\n")
        out.write(AString(parts))


def test_pipelined_send_error_surfaces_on_close_and_reader_terminates():
    name = "db://senderr?query=1"
    reader_done = threading.Event()
    reader_rows = []

    def imp():
        pipe = DataPipeInput(name)
        try:
            for b in pipe.blocks():
                reader_rows.append(len(b))
        except IOError:
            pass
        finally:
            pipe.close()
            reader_done.set()

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    # block_rows > row count: the single block is flushed inside close(),
    # so close() is the first place the sender error can possibly surface
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol", block_rows=1024,
                                                 pipelined=True))

    real_send = out._transport.send_frames

    def broken_send(kind, segs):
        if kind == FRAME_BLOCK:
            raise _BoomError("wire fell over")
        return real_send(kind, segs)

    out._transport.send_frames = broken_send
    _pump_rows(out, make_paper_block(200, seed=7))
    with pytest.raises(_BoomError):
        out.close()
    assert out.closed
    assert reader_done.wait(10), "reader must not hang after sender failure"


def test_pipelined_writer_fails_fast_after_sender_error():
    name = "db://senderr2?query=1"

    def imp():
        pipe = DataPipeInput(name)
        try:
            list(pipe.blocks())
        except IOError:
            pass
        finally:
            pipe.close()

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol", block_rows=8,
                                                 pipelined=True))

    def broken_send(kind, segs):
        raise _BoomError("wire fell over")

    out._transport.send_frames = broken_send
    block = make_paper_block(400, seed=8)
    with pytest.raises(_BoomError):
        # enough blocks that a post-latch write must observe the error
        for _ in range(50):
            _pump_rows(out, block)
    with pytest.raises(_BoomError):
        out.close()
