"""Shared-memory ring transport: in-process ring mechanics (wrap markers,
backpressure, oversize frames), the event-driven doorbell (idle wakeup
latency, poll fallback), the per-frame seqlock (torn publications gate,
corrupt headers fail loudly), and the cross-process integration contract
(two real OS processes, zero intermediate block materializations,
reader/writer-death fail-fast, unclean-shutdown segment cleanup)."""

import multiprocessing
import os
import signal
import struct
import threading
import time
from multiprocessing import shared_memory

import numpy as np
import pytest

from repro.core.datapipe import DataPipeInput, DataPipeOutput, PipeConfig
from repro.core.directory import DirectoryClient, DirectoryServer, set_directory
from repro.core.shm_ring import (
    _FRAME,
    _KL,
    _OFF_HEAD,
    _U32,
    _token,
    ShmRing,
    ShmRingTransport,
    doorbell_supported,
)
from repro.core.transport import FRAME_BLOCK, FRAME_EOF, FRAME_TEXT
from repro.engines.base import assert_blocks_equal, make_paper_block

needs_doorbell = pytest.mark.skipif(
    not doorbell_supported(), reason="platform has no eventfd/fifo doorbell")

_mp = multiprocessing.get_context("spawn")

JOIN_S = 60  # generous: spawn pays an interpreter start per child


def _join_or_kill(procs):
    deadline = time.monotonic() + JOIN_S
    for p in procs:
        p.join(max(0.1, deadline - time.monotonic()))
    hung = [p for p in procs if p.is_alive()]
    for p in hung:
        p.kill()
        p.join(5)
    assert not hung, "child process hung (shm transport must fail fast)"


# -- in-process ring mechanics ------------------------------------------------------


def test_ring_frame_roundtrip_with_wrap_markers():
    ring = ShmRing.create(capacity=4096, role="reader")
    tx = ShmRingTransport(ring)
    rx = ShmRingTransport(ring)
    # sizes chosen to stagger across the 4096-byte region repeatedly so
    # several frames hit the wrap-marker path
    sizes = [900, 1500, 700, 1200, 3000, 10, 0, 2048] * 4
    want = [bytes([i % 251]) * n for i, n in enumerate(sizes)]
    got = []

    def recv():
        for _ in sizes:
            kind, payload = rx.recv_frame()
            # a span view is only valid until the next recv: copy now
            got.append((kind, bytes(payload)))

    t = threading.Thread(target=recv, daemon=True)
    t.start()
    for payload in want:
        tx.send_frames(FRAME_BLOCK, [payload])
    t.join(JOIN_S)
    assert not t.is_alive()
    assert [p for _, p in got] == want
    assert all(k == FRAME_BLOCK for k, _ in got)
    # header-byte accounting parity with the other transports
    assert tx.bytes_sent == sum(sizes) + 5 * len(sizes)
    assert tx.shm_spans == len(sizes)
    ring.close()


def test_ring_send_gathers_segments_in_place():
    ring = ShmRing.create(capacity=1 << 16, role="reader")
    tx, rx = ShmRingTransport(ring), ShmRingTransport(ring)
    arr = np.arange(100, dtype=np.int64)
    segs = [b"head", memoryview(b"-mid-"), bytearray(b"tail"), arr.data]
    tx.send_frames(FRAME_BLOCK, segs)
    kind, payload = rx.recv_frame()
    assert kind == FRAME_BLOCK
    assert isinstance(payload, memoryview)  # consumed in place, not copied
    assert bytes(payload) == b"head-mid-tail" + arr.tobytes()
    ring.close()


def test_ring_full_applies_backpressure():
    ring = ShmRing.create(capacity=4096, role="reader")
    tx, rx = ShmRingTransport(ring), ShmRingTransport(ring)
    n_frames, payload = 32, b"x" * 1000
    sent = []

    def send():
        for i in range(n_frames):
            tx.send_frames(FRAME_TEXT, [payload])
            sent.append(i)

    t = threading.Thread(target=send, daemon=True)
    t.start()
    time.sleep(0.3)
    # at most 4 frames fit in 4096 bytes: the sender must be blocked
    assert t.is_alive() and len(sent) < n_frames
    for _ in range(n_frames):
        kind, p = rx.recv_frame()
        assert (kind, bytes(p)) == (FRAME_TEXT, payload)
    t.join(JOIN_S)
    assert len(sent) == n_frames
    ring.close()


def test_ring_rejects_frame_larger_than_capacity():
    ring = ShmRing.create(capacity=1024, role="reader")
    tx = ShmRingTransport(ring)
    with pytest.raises(IOError, match="exceeds ring capacity"):
        tx.send_frames(FRAME_BLOCK, [b"z" * 2048])
    ring.close()


def test_ring_close_unlinks_segment():
    ring = ShmRing.create(capacity=1024, role="reader")
    name = ring.name
    ring.close()
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name, create=False)
    assert ShmRing.cleanup(name) is False  # nothing left behind


# -- doorbell wakeups + seqlock -----------------------------------------------------


@needs_doorbell
def test_doorbell_wakes_idle_reader_fast():
    """An idle (deep-parked) reader wakes well under the old 2 ms poll cap
    the moment a frame is committed — and never touched the poll path."""
    ring = ShmRing.create(capacity=1 << 16, role="reader")
    tx, rx = ShmRingTransport(ring), ShmRingTransport(ring)
    lats = []
    for _ in range(5):
        sent_at = []

        def send():
            time.sleep(0.08)  # reader reaches the parked doorbell wait
            sent_at.append(time.perf_counter())
            tx.send_frames(FRAME_TEXT, [b"ping"])

        th = threading.Thread(target=send, daemon=True)
        th.start()
        kind, payload = rx.recv_frame()
        lats.append(time.perf_counter() - sent_at[0])
        assert (kind, payload) == (FRAME_TEXT, b"ping")
        th.join(JOIN_S)
    assert ring.wakeups["doorbell"] >= 5
    assert ring.wakeups["poll"] == 0
    assert min(lats) < 2e-3, f"idle wakeup latencies {lats}"
    ring.close()


def _child_latency_writer(name, rounds):
    ring = ShmRing.attach(name, role="writer")
    tx = ShmRingTransport(ring)
    for _ in range(rounds):
        time.sleep(0.08)  # parent reader parks idle on the doorbell
        # CLOCK_MONOTONIC is system-wide on Linux: stamp the send time
        tx.send_frames(FRAME_TEXT, [struct.pack("<d", time.monotonic())])
    tx.send_frames(FRAME_EOF, [b""])
    tx.close()


@needs_doorbell
def test_multiprocess_doorbell_wakeup_latency():
    """The doorbell crosses process lines (the per-ring named pipe): an
    idle reader in THIS process wakes microseconds after a writer in a
    child process commits, not after a poll-backoff quantum."""
    ring = ShmRing.create(capacity=1 << 16, role="reader")
    p = _mp.Process(target=_child_latency_writer, args=(ring.name, 5))
    p.start()
    rx = ShmRingTransport(ring)
    lats = []
    while True:
        kind, payload = rx.recv_frame()
        if kind == FRAME_EOF:
            break
        lats.append(time.monotonic() - struct.unpack("<d", payload)[0])
    _join_or_kill([p])
    assert len(lats) == 5
    assert ring.wakeups["poll"] == 0
    assert ring.wakeups["doorbell"] > 0
    assert min(lats) < 2e-3, f"cross-process wakeup latencies {lats}"
    rx.close()


def test_seqlock_gates_torn_frame_until_commit():
    """A frame whose commit word was never stored (a writer dying between
    payload and publication, or head visible before payload off-TSO) reads
    as 'not ready' — never as a frame; storing the token releases it."""
    ring = ShmRing.create(capacity=4096, role="reader", doorbell=False)
    payload = b"torn"
    # hand-craft what an interrupted publication leaves behind: kind,
    # length and payload written, head advanced, commit word still clear
    _U32.pack_into(ring._data, 0, 0)
    _KL.pack_into(ring._data, _U32.size, FRAME_TEXT, len(payload))
    ring._data[_FRAME.size:_FRAME.size + len(payload)] = payload
    ring._set_u64(_OFF_HEAD, _FRAME.size + len(payload))
    with pytest.raises(TimeoutError):
        ring.recv(timeout=0.2)
    # a mismatched (stale-lap) token is equally not-ready
    _U32.pack_into(ring._data, 0, _token(12345))
    with pytest.raises(TimeoutError):
        ring.recv(timeout=0.2)
    # completing the publication releases the frame
    _U32.pack_into(ring._data, 0, _token(0))
    kind_byte, view = ring.recv(timeout=5.0)
    assert (kind_byte, bytes(view)) == (FRAME_TEXT[0], payload)
    ring.close()


def test_seqlock_corrupt_length_fails_loudly():
    ring = ShmRing.create(capacity=4096, role="reader", doorbell=False)
    _U32.pack_into(ring._data, 0, _token(0))
    _KL.pack_into(ring._data, _U32.size, FRAME_BLOCK, 999_999)
    ring._set_u64(_OFF_HEAD, 64)
    with pytest.raises(IOError, match="corrupt"):
        ring.recv(timeout=5.0)
    ring.close()


def test_pooled_ring_reuse_does_not_resurrect_stale_frames():
    """reset() rewinds the monotonic cursors but leaves the previous
    lease's frames (whose commit words are token-valid again — tokens
    derive from the byte offset alone) in the data region: the head gate
    must keep the next lease's reader from consuming them before its own
    writer publishes anything."""
    from repro.core.shm_ring import acquire_ring, attach_ring

    cap = 24576  # capacity no other test parks, so the pool hit is ours
    ring = acquire_ring(cap)
    tx = ShmRingTransport(attach_ring(ring.name))
    rx = ShmRingTransport(ring)
    tx.send_frames(FRAME_TEXT, [b"lease-one"])
    tx.send_frames(FRAME_EOF, [b""])
    assert rx.recv_frame() == (FRAME_TEXT, b"lease-one")
    assert rx.recv_frame() == (FRAME_EOF, b"")
    rx.close()  # clean EOF: parks the ring warm
    tx.close()
    ring2 = acquire_ring(cap)
    assert ring2 is ring  # same segment, stale frames still in the region
    # the new lease's reader polls before its writer attached: the stale
    # lease-one frame at offset 0 must read as "nothing published"
    with pytest.raises(TimeoutError):
        ring2.recv(timeout=0.2)
    # the epoch key guards even the weakly-ordered worst case (head
    # visible before the new frame's stores): with head hand-advanced
    # over the STALE lease-one commit word, the word must still mismatch
    assert ring2._epoch != 0  # reset() bumped the lease epoch
    ring2._set_u64(_OFF_HEAD, 64)
    with pytest.raises(TimeoutError):
        ring2.recv(timeout=0.2)
    ring2._set_u64(_OFF_HEAD, 0)
    tx2 = ShmRingTransport(attach_ring(ring2.name))
    rx2 = ShmRingTransport(ring2)
    tx2.send_frames(FRAME_TEXT, [b"lease-two"])
    assert rx2.recv_frame() == (FRAME_TEXT, b"lease-two")
    tx2.send_frames(FRAME_EOF, [b""])
    assert rx2.recv_frame() == (FRAME_EOF, b"")
    tx2.close()
    rx2.ring.reader_close()  # unlink: leave nothing parked behind


def test_poll_fallback_keeps_shm_transfers_green(monkeypatch):
    """Where the doorbell machinery is unavailable the ring must degrade
    to the backoff poll — visibly (poll_sleeps counted) but correctly."""
    import repro.core.shm_ring as sr

    monkeypatch.setattr(sr, "_DOORBELL_OK", False)
    from repro.core.directory import WorkerDirectory, set_directory as setd

    setd(WorkerDirectory())
    name = "db://fallback-shm?query=1"
    block = make_paper_block(3000, seed=9)
    got = {}

    def imp():
        pipe = DataPipeInput(name, transport="shm", shm_capacity=1 << 20)
        got["blocks"] = list(pipe.blocks())
        pipe.close()
        got["stats"] = pipe.stats

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol",
                                                 block_rows=512))
    out.write_block(block)
    out.close()
    t.join(JOIN_S)
    assert not t.is_alive()
    from repro.core.types import ColumnBlock

    assert_blocks_equal(block, ColumnBlock.concat(got["blocks"]),
                        check_names=False)
    assert got["stats"].doorbell_waits == 0
    assert got["stats"].poll_sleeps > 0  # the importer idled in the poll


# -- cross-process children ---------------------------------------------------------


def _child_importer(dir_addr, name, q):
    set_directory(DirectoryClient(*dir_addr))
    pipe = DataPipeInput(name, transport="shm", shm_capacity=1 << 20)
    ring_name = pipe._transport.ring.name
    rows = 0
    key_sum = 0
    for block in pipe.blocks():
        rows += len(block)
        key_sum += int(np.asarray(block.columns[0]).sum())
    pipe.close()
    q.put(("ok", rows, key_sum, pipe.stats.shm_spans,
           pipe.stats.decode_pool_hits, ring_name))


def _child_exporter(dir_addr, name, n_rows, q):
    set_directory(DirectoryClient(*dir_addr))
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol",
                                                 block_rows=512))
    out.write_block(make_paper_block(n_rows, seed=11))
    out.close()
    q.put(("ok", out.stats.copies_avoided, out.stats.shm_spans,
           out.stats.frames_sent))


def _child_reader_then_die(name, attached):
    ring = ShmRing.attach(name, role="reader")
    t = ShmRingTransport(ring)
    attached.set()
    t.recv_frame()  # take one frame, then die without closing
    os.kill(os.getpid(), signal.SIGKILL)


def _child_writer_then_die(name, frames_before_death):
    ring = ShmRing.attach(name, role="writer")
    t = ShmRingTransport(ring)
    for i in range(frames_before_death):
        t.send_frames(FRAME_TEXT, [b"frame-%d" % i])
    os.kill(os.getpid(), signal.SIGKILL)  # no EOF frame, no close


def test_shm_pipe_between_two_processes():
    """The acceptance transfer: exporter and importer in separate OS
    processes, zero intermediate block materializations."""
    n_rows = 20_000
    server = DirectoryServer().start()
    try:
        q = _mp.Queue()
        name = "db://xproc?query=s1"
        imp = _mp.Process(target=_child_importer,
                          args=((server.host, server.port), name, q))
        exp = _mp.Process(target=_child_exporter,
                          args=((server.host, server.port), name, n_rows, q))
        imp.start()
        exp.start()
        results = [q.get(timeout=JOIN_S), q.get(timeout=JOIN_S)]
        _join_or_kill([imp, exp])
        by_len = {len(r): r for r in results}
        _, copies_avoided, exp_spans, frames_sent = by_len[4]
        _, rows, key_sum, imp_spans, decode_hits, ring_name = by_len[6]
        assert rows == n_rows
        assert key_sum == n_rows * (n_rows - 1) // 2  # key column intact
        # zero intermediate materializations: every frame crossed as an
        # in-place span, and the fixed columns went in as live views
        assert exp_spans == frames_sent
        assert copies_avoided > 0
        assert imp_spans > 0  # block payloads decoded in place
        assert decode_hits > 0  # decode arena recycled stores across blocks
        # the importer unlinked the segment on close (no leak)
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=ring_name, create=False)
    finally:
        server.stop()


def test_writer_fails_fast_when_reader_dies():
    ring = ShmRing.create(capacity=8192, role="writer")
    try:
        attached = _mp.Event()
        p = _mp.Process(target=_child_reader_then_die,
                        args=(ring.name, attached))
        p.start()
        assert attached.wait(JOIN_S)
        tx = ShmRingTransport(ring, send_timeout=30.0)
        with pytest.raises(BrokenPipeError):
            for i in range(1000):  # ring fills, then the pid probe fires
                tx.send_frames(FRAME_TEXT, [b"y" * 1024])
        _join_or_kill([p])
    finally:
        ring.close()


def test_reader_sees_eof_when_writer_dies_uncleanly_and_cleans_up():
    ring = ShmRing.create(capacity=8192, role="reader")
    name = ring.name
    p = _mp.Process(target=_child_writer_then_die, args=(name, 3))
    p.start()
    rx = ShmRingTransport(ring)
    got = []
    while True:
        kind, payload = rx.recv_frame()
        if kind == FRAME_EOF:  # synthesized from writer death, ring drained
            break
        got.append(bytes(payload))
    _join_or_kill([p])
    assert got == [b"frame-0", b"frame-1", b"frame-2"]
    rx.close()  # owner close: unclean shutdown must still unlink the segment
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name, create=False)


def test_shm_transport_charges_header_bytes_like_socket_and_channel():
    from repro.core.transport import Channel, ChannelTransport

    payload = b"x" * 1000
    ch = Channel()
    ct = ChannelTransport(ch)
    ct.send_frame(FRAME_TEXT, payload)
    ring = ShmRing.create(capacity=1 << 16, role="reader")
    st = ShmRingTransport(ring)
    st.send_frame(FRAME_TEXT, payload)
    assert st.bytes_sent == ct.bytes_sent == len(payload) + 5
    ring.close()


def test_in_process_shm_transfer_matches_channel_semantics():
    """Same-process transfer over shm (threads), exercising EOF frames,
    schema negotiation and the decode arena plumbing end to end."""
    from repro.core.directory import WorkerDirectory, set_directory as setd

    setd(WorkerDirectory())
    name = "db://inproc-shm?query=1"
    block = make_paper_block(4000, seed=5, strings=True)
    got = {}

    def imp():
        pipe = DataPipeInput(name, transport="shm", shm_capacity=1 << 20)
        got["blocks"] = list(pipe.blocks())
        pipe.close()
        got["stats"] = pipe.stats

    t = threading.Thread(target=imp, daemon=True)
    t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol",
                                                 block_rows=777))
    out.write_block(block)
    out.close()
    t.join(JOIN_S)
    assert not t.is_alive()
    from repro.core.types import ColumnBlock

    assert_blocks_equal(block, ColumnBlock.concat(got["blocks"]),
                        check_names=False)
    assert out.stats.shm_spans == out.stats.frames_sent
    assert got["stats"].shm_spans > 0
    if doorbell_supported():
        # a regression back to polling is a latency bug: the importer's
        # idle waits must resolve through the doorbell (or the brief spin)
        assert got["stats"].poll_sleeps == 0
        assert got["stats"].doorbell_waits + got["stats"].spin_wakeups > 0
