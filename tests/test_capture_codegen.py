"""Compile loop (sections 3.2/4.1/6): capture discrimination, adapter
generation, Table-2 stats, and the verification proxy."""

import builtins
import os

import pytest

from repro.core import (
    PipeConfig,
    PipeEnabledEngine,
    adapter_for,
    generate_pipe_adapter,
    validate_generated_pipe,
)
from repro.core.capture import run_capture
from repro.core.ioredirect import PipeOpenContext
from repro.engines import ENGINES, make_engine


def test_capture_rejects_unrelated_opens(tmp_path):
    """The paper's debug-log case: an open() of another file must NOT be
    turned into a pipe call site."""
    target = str(tmp_path / "data.csv")
    log = str(tmp_path / "debug.log")
    eng = make_engine("colstore")

    def export_test(path):
        with open(log, "w") as f:   # unrelated open
            f.write("dbg")
        eng.unit_export_test(path)

    report = run_capture("colstore", export_test, eng.unit_import_test, target)
    assert report.export_sites and report.import_sites
    rejected_files = {
        fn for s in report.rejected_sites for fn in [log]
    }
    assert report.rejected_sites, "the debug-log site must be rejected"
    for site in report.sites:
        assert site not in report.rejected_sites


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_adapter_generation_and_stats(name, tmp_path):
    eng = make_engine(name)
    gp = generate_pipe_adapter(
        name, eng.unit_export_test, eng.unit_import_test,
        str(tmp_path / "unit.csv"), out_dir=tmp_path / "gen",
    )
    # Table 2 reproduction: stats must be populated and small
    assert gp.stats.ioredirect_classes >= 1
    assert gp.stats.ioredirect_loc > 0
    assert gp.stats.modification_time_s < 60
    assert (tmp_path / "gen" / f"{name}_pipe.py").exists()
    src = gp.adapter_source
    assert "REGISTRY" in src and "PipeOpen" in src


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_verification_proxy_roundtrip(name, tmp_path):
    """Section 4.1: unit tests run across the proxy validate the pipe."""
    eng = make_engine(name)
    gp = adapter_for(eng)
    with PipeEnabledEngine(gp), PipeOpenContext(PipeConfig(mode="arrowcol")):
        res = validate_generated_pipe(
            name, eng.unit_roundtrip_test, tmp_path,
            dataset=f"vrt-{name}")
    assert res.passed, res.detail


def test_splice_restores_builtin_open(tmp_path):
    eng = make_engine("rowstore")
    gp = adapter_for(eng)
    real = builtins.open
    with PipeEnabledEngine(gp):
        pass
    assert builtins.open is real


def test_nested_splices_compose(tmp_path):
    a, b = make_engine("rowstore"), make_engine("dataframe")
    real = builtins.open
    with PipeEnabledEngine(adapter_for(a)):
        with PipeEnabledEngine(adapter_for(b)):
            assert builtins.open is not real
        assert builtins.open is not real
    assert builtins.open is real


def test_negotiate_pipe_mode_prefers_arrowcol(tmp_path):
    """Paper sections 5.1/5.2: the optimization ladder picks the most
    optimized rung that passes the engine's unit tests across the proxy."""
    from repro.core.session import negotiate_pipe_mode

    eng = make_engine("colstore")
    cfg = negotiate_pipe_mode(eng, spool_dir=str(tmp_path))
    assert cfg.mode == "arrowcol"


def test_negotiate_pipe_mode_falls_back_on_failure(tmp_path, monkeypatch):
    """A broken optimized rung must be disabled, falling to the next."""
    from repro.core import session as sess
    from repro.core.session import negotiate_pipe_mode
    from repro.core import verify as verify_mod

    real_validate = verify_mod.validate_generated_pipe
    calls = []

    def flaky(engine_name, rt, spool, dataset=None, directory=None,
              config=None):
        calls.append(config.mode)
        if config.mode == "arrowcol":  # simulate a failing optimized rung
            from repro.core.verify import VerificationResult
            return VerificationResult(engine_name, False, "injected failure")
        return real_validate(engine_name, rt, spool, dataset=dataset,
                             directory=directory, config=config)

    monkeypatch.setattr("repro.core.verify.validate_generated_pipe", flaky)
    eng = make_engine("dataframe")
    cfg = negotiate_pipe_mode(eng, spool_dir=str(tmp_path))
    assert calls[0] == "arrowcol"
    assert cfg.mode == "arrowrow"  # next rung down
