"""FormOpt (section 5): delimiter inference, assemblers, metadata removal."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.astring import AString
from repro.core.formopt import (
    DelimitedAssembler,
    JsonAssembler,
    infer_delimiter,
    render_delimited,
)
from repro.core.types import RowBlock


# -- section 5.3.1: the paper's own inference examples ------------------------

def test_paper_unambiguous_example():
    # [1, "|", "a,b", "\n"] -> exactly one length-one string
    assert infer_delimiter([1, "|", "a,b", "\n"]) == "|"


def test_paper_tiebreak_prefers_non_alphanumeric():
    # [1, "|", "a", "\n"]: "|" and "a" tie; prefer non-alphanumeric
    assert infer_delimiter([1, "|", "a", "\n"]) == "|"


def test_paper_tiebreak_prefers_earlier():
    # two non-alphanumeric candidates with equal counts: earlier one wins
    assert infer_delimiter([1, "|", 2, ";", 3]) in ("|",)


def test_row_terminators_excluded():
    assert infer_delimiter(["\n", "\n", ",", 1]) == ","


def test_delimited_assembler_typed_rows():
    asm = DelimitedAssembler(sample_rows=2)
    for row in [(1, 2.5, "x"), (2, 3.5, "y"), (3, 4.5, "z")]:
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append(",")
            parts.append(v)
        parts.append("\n")
        asm.write(AString(parts))
    asm.flush()
    rb = asm.take_rows()
    assert rb.rows == [(1, 2.5, "x"), (2, 3.5, "y"), (3, 4.5, "z")]
    assert asm.delimiter == ","


def test_header_detection():
    asm = DelimitedAssembler(sample_rows=2)
    rows = [("key", "val"), (1, 2.5), (2, 3.5)]
    for row in rows:
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append(",")
            parts.append(v)
        parts.append("\n")
        asm.write(AString(parts))
    asm.flush()
    rb = asm.take_rows()
    assert asm.header_names == ("key", "val")
    assert rb.schema.names == ("key", "val")
    assert rb.rows == [(1, 2.5), (2, 3.5)]


# -- section 5.3.2: JSON key-header dedup --------------------------------------

def _feed_json(asm, docs):
    for d in docs:
        parts = ["{"]
        for j, (k, v) in enumerate(d.items()):
            if j:
                parts.append(", ")
            parts.extend(['"', k, '": '])
            parts.append(v)
        parts.append("}\n")
        asm.write(AString(parts))
    asm.flush()


def test_json_key_header_once():
    asm = JsonAssembler()
    _feed_json(asm, [{"a": 1, "b": 2}, {"a": 3, "b": 4}])
    rb = asm.take_rows()
    assert asm.key_header == ["a", "b"]
    assert rb.schema.names == ("a", "b")
    assert rb.rows == [(1, 2), (3, 4)]


def test_json_superset_extends_header():
    # paper: superset keys are appended (missing-value case)
    asm = JsonAssembler()
    _feed_json(asm, [{"a": 1}, {"a": 2, "b": 3}])
    asm.take_rows()
    assert asm.key_header == ["a", "b"]


def test_json_disjoint_disables_optimization():
    asm = JsonAssembler()
    _feed_json(asm, [{"a": 1}, {"z": 9}])
    asm.take_rows()
    assert asm.raw_rows == [{"z": 9}]  # transmitted with its own keys


# -- property: assembler inverts rendering -------------------------------------

# string cells are >= 2 chars: a length-1 data cell legitimately ties with
# the delimiter in section 5.3.1's frequency heuristic (the paper's answer
# is "unit tests fail -> disable the optimization", not a different guess)
_COL_STRATS = (
    st.integers(-10**6, 10**6),
    st.floats(-1e6, 1e6, allow_nan=False),
    st.text(alphabet="abcdefgh", min_size=2, max_size=6),
)


@st.composite
def _typed_rows(draw):
    """Rows with type-homogeneous columns (schema is sniffed from row 0,
    exactly like the engines' file import path)."""
    col_types = draw(st.lists(st.sampled_from(_COL_STRATS), min_size=3,
                              max_size=3))
    n = draw(st.integers(2, 12))
    return [tuple(draw(t) for t in col_types) for _ in range(n)]


@given(_typed_rows())
@settings(max_examples=40, deadline=None)
def test_assembler_inverts_decorated_writer(rows):
    asm = DelimitedAssembler(sample_rows=4)
    for row in rows:
        parts = []
        for j, v in enumerate(row):
            if j:
                parts.append("|")
            parts.append(v)
        parts.append("\n")
        asm.write(AString(parts))
    asm.flush()
    rb = asm.take_rows()
    assert asm.delimiter == "|"
    assert len(rb.rows) == len(rows)
    for got, want in zip(rb.rows, rows):
        for g, w in zip(got, want):
            if isinstance(w, float):
                assert g == pytest.approx(w)
            else:
                assert g == w
