"""Buffer pool / scatter-gather primitives (zero-copy transfer hot path)."""

import struct

import numpy as np
import pytest

from repro.core.iobuf import (
    MAX_CLASS,
    MIN_CLASS,
    BufferPool,
    BufWriter,
    DecodeArena,
    SegmentList,
    default_decode_pool,
    default_pool,
)


def test_pool_size_classes_and_reuse():
    pool = BufferPool()
    a = pool.acquire(100)
    assert len(a.store) == MIN_CLASS  # rounded up to the smallest class
    store_id = id(a.store)
    a.release()
    b = pool.acquire(1000)  # same class -> same backing store comes back
    assert id(b.store) == store_id
    assert pool.stats.hits == 1 and pool.stats.misses == 1
    b.release()


def test_pool_oversize_requests_fall_through():
    pool = BufferPool()
    big = pool.acquire(MAX_CLASS + 1)
    assert len(big.store) == MAX_CLASS + 1
    big.release()  # not retained: oversize buffers go to GC
    assert pool.stats.bytes_retained == 0
    again = pool.acquire(MAX_CLASS + 1)
    assert pool.stats.misses == 2


def test_pool_bounded_retention():
    pool = BufferPool(max_per_class=2)
    bufs = [pool.acquire(MIN_CLASS) for _ in range(5)]
    for b in bufs:
        b.release()
    assert pool.stats.bytes_retained == 2 * MIN_CLASS


def test_pooled_release_is_idempotent():
    pool = BufferPool()
    a = pool.acquire(10)
    a.release()
    a.release()  # second release is a no-op, not a double-park
    assert pool.stats.releases == 1


def test_segment_list_join_and_nbytes():
    segs = SegmentList([b"ab", memoryview(b"cd"), bytearray(b"ef")])
    assert segs.nbytes == 6
    assert segs.join() == b"abcdef"
    arr = np.arange(4, dtype=np.int64)
    segs.append(arr.data, zero_copy=True)
    assert segs.nbytes == 6 + 32
    assert segs.join() == b"abcdef" + arr.tobytes()
    assert segs.copies_avoided == 1


def test_segment_list_release_recycles_pooled():
    pool = BufferPool()
    buf = pool.acquire(64)
    buf.store[:3] = b"xyz"
    segs = SegmentList()
    segs.append_pooled(buf)
    assert segs.join() == b"xyz" + bytes(61)
    segs.release()
    assert pool.stats.releases == 1
    assert segs.segments == []  # views are dead after release


def test_bufwriter_grows_through_classes():
    pool = BufferPool()
    w = BufWriter(pool, size_hint=16)
    payload = bytes(range(256)) * 20  # 5120 bytes > MIN_CLASS
    for i in range(0, len(payload), 100):
        w.write(payload[i : i + 100])
    st = struct.Struct("<I")
    w.pack_into(st, 0xDEADBEEF)
    segs = w.detach()
    assert segs.join() == payload + st.pack(0xDEADBEEF)
    segs.release()
    assert pool.stats.releases >= 1


def test_bufwriter_pack_into_across_growth_boundary():
    pool = BufferPool()
    w = BufWriter(pool, size_hint=MIN_CLASS)
    w.write(b"a" * (MIN_CLASS - 2))  # leaves 2 bytes of room
    st = struct.Struct("<q")  # needs 8 -> forces growth mid-pack
    w.pack_into(st, -12345)
    segs = w.detach()
    data = segs.join()
    assert data[: MIN_CLASS - 2] == b"a" * (MIN_CLASS - 2)
    assert struct.unpack_from("<q", data, MIN_CLASS - 2)[0] == -12345
    segs.release()


def test_default_pool_is_singleton():
    assert default_pool() is default_pool()
    assert default_decode_pool() is default_decode_pool()
    assert default_decode_pool() is not default_pool()  # stats stay separate


# -- decode arena -------------------------------------------------------------------


def test_decode_arena_recycles_after_collection():
    arena = DecodeArena(BufferPool())
    a = arena.array(np.int64, 100)
    a[:] = np.arange(100)
    assert arena.misses == 1 and arena.hits == 0 and arena.live == 1
    del a  # no views left -> store returns to the pool promptly
    b = arena.array(np.int64, 64)
    assert arena.hits == 1 and arena.live == 1
    del b


def test_decode_arena_hit_rate_across_blocks():
    """Streaming decode profile: block N's stores are reclaimed once the
    consumer drops the block, so block N+1 allocates nothing."""
    from repro.core.wire import get_wire_format
    from repro.engines.base import assert_blocks_equal, make_paper_block

    arena = DecodeArena(BufferPool())
    wire = get_wire_format("arrowcol")
    block = make_paper_block(512, seed=3)
    payload = wire.encode_block(block).join()
    decoded = wire.decode_block(payload, block.schema, arena=arena)
    assert_blocks_equal(block, decoded)
    first_misses = arena.misses
    assert first_misses > 0 and arena.hits == 0
    del decoded
    for _ in range(4):  # steady state: every fixed column is a pool hit
        decoded = wire.decode_block(payload, block.schema, arena=arena)
        del decoded
    assert arena.misses == first_misses
    assert arena.hits == 4 * first_misses
    total = arena.hits + arena.misses
    assert arena.hits / total >= 0.75


def test_decode_arena_never_aliases_live_output():
    """Regression: decode_block output views must not alias recycled
    buffers -- a store is recycled only after its arrays (and views) die."""
    from repro.core.wire import get_wire_format
    from repro.engines.base import make_paper_block

    arena = DecodeArena(BufferPool())
    wire = get_wire_format("arrowcol")
    a_block = make_paper_block(256, seed=1)
    b_block = make_paper_block(256, seed=2)
    payload_a = wire.encode_block(a_block).join()
    payload_b = wire.encode_block(b_block).join()

    got_a = wire.decode_block(payload_a, a_block.schema, arena=arena)
    keys_a = got_a.column("key")
    snapshot = keys_a.copy()
    got_b = wire.decode_block(payload_b, b_block.schema, arena=arena)
    # a live block's stores are never handed to a second decode
    for ca in got_a.columns:
        for cb in got_b.columns:
            if hasattr(ca, "dtype") and hasattr(cb, "dtype"):
                assert not np.shares_memory(ca, cb)
    np.testing.assert_array_equal(keys_a, snapshot)

    # a *view* keeps the store leased even after its block is released
    view = keys_a[10:20]
    del got_a, keys_a
    wire.decode_block(payload_b, b_block.schema, arena=arena)
    np.testing.assert_array_equal(view, snapshot[10:20])

    # once every reference is gone the store recycles (pool hits)
    del view, got_b
    before = arena.hits
    wire.decode_block(payload_a, a_block.schema, arena=arena)
    assert arena.hits > before
