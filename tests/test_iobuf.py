"""Buffer pool / scatter-gather primitives (zero-copy transfer hot path)."""

import struct

import numpy as np
import pytest

from repro.core.iobuf import (
    MAX_CLASS,
    MIN_CLASS,
    BufferPool,
    BufWriter,
    SegmentList,
    default_pool,
)


def test_pool_size_classes_and_reuse():
    pool = BufferPool()
    a = pool.acquire(100)
    assert len(a.store) == MIN_CLASS  # rounded up to the smallest class
    store_id = id(a.store)
    a.release()
    b = pool.acquire(1000)  # same class -> same backing store comes back
    assert id(b.store) == store_id
    assert pool.stats.hits == 1 and pool.stats.misses == 1
    b.release()


def test_pool_oversize_requests_fall_through():
    pool = BufferPool()
    big = pool.acquire(MAX_CLASS + 1)
    assert len(big.store) == MAX_CLASS + 1
    big.release()  # not retained: oversize buffers go to GC
    assert pool.stats.bytes_retained == 0
    again = pool.acquire(MAX_CLASS + 1)
    assert pool.stats.misses == 2


def test_pool_bounded_retention():
    pool = BufferPool(max_per_class=2)
    bufs = [pool.acquire(MIN_CLASS) for _ in range(5)]
    for b in bufs:
        b.release()
    assert pool.stats.bytes_retained == 2 * MIN_CLASS


def test_pooled_release_is_idempotent():
    pool = BufferPool()
    a = pool.acquire(10)
    a.release()
    a.release()  # second release is a no-op, not a double-park
    assert pool.stats.releases == 1


def test_segment_list_join_and_nbytes():
    segs = SegmentList([b"ab", memoryview(b"cd"), bytearray(b"ef")])
    assert segs.nbytes == 6
    assert segs.join() == b"abcdef"
    arr = np.arange(4, dtype=np.int64)
    segs.append(arr.data, zero_copy=True)
    assert segs.nbytes == 6 + 32
    assert segs.join() == b"abcdef" + arr.tobytes()
    assert segs.copies_avoided == 1


def test_segment_list_release_recycles_pooled():
    pool = BufferPool()
    buf = pool.acquire(64)
    buf.store[:3] = b"xyz"
    segs = SegmentList()
    segs.append_pooled(buf)
    assert segs.join() == b"xyz" + bytes(61)
    segs.release()
    assert pool.stats.releases == 1
    assert segs.segments == []  # views are dead after release


def test_bufwriter_grows_through_classes():
    pool = BufferPool()
    w = BufWriter(pool, size_hint=16)
    payload = bytes(range(256)) * 20  # 5120 bytes > MIN_CLASS
    for i in range(0, len(payload), 100):
        w.write(payload[i : i + 100])
    st = struct.Struct("<I")
    w.pack_into(st, 0xDEADBEEF)
    segs = w.detach()
    assert segs.join() == payload + st.pack(0xDEADBEEF)
    segs.release()
    assert pool.stats.releases >= 1


def test_bufwriter_pack_into_across_growth_boundary():
    pool = BufferPool()
    w = BufWriter(pool, size_hint=MIN_CLASS)
    w.write(b"a" * (MIN_CLASS - 2))  # leaves 2 bytes of room
    st = struct.Struct("<q")  # needs 8 -> forces growth mid-pack
    w.pack_into(st, -12345)
    segs = w.detach()
    data = segs.join()
    assert data[: MIN_CLASS - 2] == b"a" * (MIN_CLASS - 2)
    assert struct.unpack_from("<q", data, MIN_CLASS - 2)[0] == -12345
    segs.release()


def test_default_pool_is_singleton():
    assert default_pool() is default_pool()
