"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.decode_attn.ref import decode_attention_ref
from repro.kernels.flashattn.ops import attention
from repro.kernels.flashattn.ref import attention_ref
from repro.kernels.mamba2_ssd.ops import ssd
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.pivot.ops import pivot, pivot_columns
from repro.kernels.pivot.ref import pivot_ref, unpivot_ref
from repro.kernels.rwkv6_scan.ops import wkv
from repro.kernels.rwkv6_scan.ref import wkv_ref


# -- pivot ------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 8), (256, 256), (300, 70), (1, 513)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_pivot_sweep(shape, dtype):
    x = jax.random.randint(jax.random.PRNGKey(0), shape, 0, 1 << 20
                           ).astype(dtype)
    np.testing.assert_array_equal(np.asarray(pivot(x, interpret=True)),
                                  np.asarray(x).T)


@given(st.integers(1, 70), st.integers(1, 70))
@settings(max_examples=15, deadline=None)
def test_pivot_property(n, w):
    x = jnp.arange(n * w, dtype=jnp.int32).reshape(n, w)
    np.testing.assert_array_equal(np.asarray(pivot(x, interpret=True)),
                                  np.asarray(x).T)


def test_pivot_columns_and_unpivot():
    rows = jax.random.randint(jax.random.PRNGKey(1), (100, 24), 0, 99,
                              dtype=jnp.int32)
    widths = [2, 4, 2, 16]
    cols = pivot_columns(rows, widths, interpret=True)
    refs = pivot_ref(rows, widths)
    for a, b in zip(cols, refs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(unpivot_ref(cols)),
                                  np.asarray(rows))


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd,causal", [
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 384, 8, 8, 32, False),
    (2, 256, 256, 6, 2, 64, True),
    (1, 512, 512, 2, 1, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flashattn_sweep(B, Sq, Sk, H, KV, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + Sk), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), dtype)
    got = attention(q, k, v, causal=causal, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flashattn_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 64))
    k = jax.random.normal(ks[1], (1, 512, 2, 64))
    v = jax.random.normal(ks[2], (1, 512, 2, 64))
    a = attention(q, k, v, interpret=True, blk_q=128, blk_k=128)
    b = attention(q, k, v, interpret=True, blk_q=256, blk_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# -- decode attention ------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,length", [
    (2, 1024, 8, 2, 64, 700),
    (1, 512, 4, 4, 32, 512),
    (2, 2048, 8, 2, 64, 1),
    (1, 1024, 16, 2, 128, 1000),
])
def test_decode_attn_sweep(B, S, H, KV, hd, length):
    ks = jax.random.split(jax.random.PRNGKey(S + length), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, S, KV, hd))
    vc = jax.random.normal(ks[2], (B, S, KV, hd))
    got = decode_attn(q, kc, vc, length, interpret=True)
    want = decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -- rwkv6 wkv -----------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 128, 2, 16, 32),
    (1, 256, 4, 32, 64),
    (1, 96, 1, 64, 32),
])
def test_rwkv6_wkv_sweep(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7 + S), 6)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    st0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    y1, s1 = wkv(r, k, v, w, u, st0, interpret=True, chunk=chunk)
    y2, s2 = wkv_ref(r, k, v, w, u, st0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


# -- mamba2 ssd ------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (2, 128, 2, 16, 16, 32),
    (1, 256, 4, 32, 32, 64),
    (1, 64, 1, 64, 64, 64),
])
def test_mamba2_ssd_sweep(B, S, H, hd, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(9 + S), 6)
    x = jax.random.normal(ks[0], (B, S, H, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    D = jnp.ones((H,))
    st0 = jax.random.normal(ks[5], (B, H, hd, N)) * 0.1
    y1, s1 = ssd(x, dt, A, Bm, Cm, D, st0, interpret=True, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, Bm, Cm, D, st0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    """Chunk-parallel dual form must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    B, S, H, hd, N = 1, 128, 2, 16, 16
    x = jax.random.normal(ks[0], (B, S, H, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    D = jnp.ones((H,))
    st0 = jnp.zeros((B, H, hd, N))
    y32, _ = ssd(x, dt, A, Bm, Cm, D, st0, interpret=True, chunk=32)
    y64, _ = ssd(x, dt, A, Bm, Cm, D, st0, interpret=True, chunk=64)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64),
                               rtol=2e-4, atol=2e-4)
