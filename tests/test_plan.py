"""Plan/compile/execute transfer API (repro.core.plan).

Covers: builder validation (cycles, duplicate targets, unknown partition
specs/options), explain() decision records, back-compat parity between
``transfer()`` and a one-edge plan, chained A→B→C and fan-out A→{B,C}
execution across two transports, streams×partition composition on socket
and shm, planner-stamped global range bounds, and all-sides error
aggregation with ``__context__`` chaining.
"""

import numpy as np
import pytest

from repro.core import (
    PipeConfig,
    PlanError,
    PlanExecutionError,
    plan,
    transfer,
)
from repro.core.directory import WorkerDirectory, set_directory
from repro.core.fabric import compute_range_bounds, parse_partition
from repro.engines import make_engine, make_paper_block


def _key_sorted(block):
    return np.sort(np.asarray(block.columns[0]))


def _rows_sorted(block):
    return sorted(map(repr, block.to_rows().rows))


# -- builder validation --------------------------------------------------------


def test_empty_plan_rejected():
    with pytest.raises(PlanError, match="empty plan"):
        plan().compile()


def test_then_without_move_rejected():
    a, b = make_engine("colstore"), make_engine("dataframe")
    with pytest.raises(PlanError, match="preceding move"):
        plan().then(a, "t", b, "t2")


def test_duplicate_target_rejected():
    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", make_paper_block(10))
    with pytest.raises(PlanError, match="duplicate target"):
        (plan(negotiate=False)
         .move(a, "t", b, "t2")
         .move(a, "t", b, "t2")
         .compile())


def test_self_cycle_rejected():
    a = make_engine("colstore")
    a.put_block("t", make_paper_block(10))
    with pytest.raises(PlanError, match="cycle"):
        plan(negotiate=False).move(a, "t", a, "t").compile()


def test_unknown_partition_spec_rejected():
    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", make_paper_block(10))
    with pytest.raises(PlanError, match="unknown partition spec"):
        plan(negotiate=False).move(a, "t", b, "t2", partition="zorp").compile()


def test_unknown_option_rejected():
    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", make_paper_block(10))
    with pytest.raises(PlanError, match="unknown option"):
        plan(negotiate=False).move(a, "t", b, "t2", frobnicate=1).compile()


def test_missing_source_table_rejected():
    a, b = make_engine("colstore"), make_engine("dataframe")
    with pytest.raises(PlanError, match="does not exist"):
        plan(negotiate=False).move(a, "nope", b, "t2").compile()


def test_files_edge_rejects_pipe_options():
    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", make_paper_block(10))
    with pytest.raises(PlanError, match="via='files' cannot take"):
        (plan(negotiate=False)
         .move(a, "t", b, "t2", via="files", partition="hash", streams=4)
         .compile())
    with pytest.raises(PlanError, match="via='files' cannot take"):
        (plan(negotiate=False)
         .move(a, "t", b, "t2", via="files", config=PipeConfig())
         .compile())


def test_compiled_plan_is_re_executable():
    """execute() twice on one CompiledPlan: fresh query ids per run keep
    the rendezvous (and the slotted shuffle's sender counter) apart."""
    blk = make_paper_block(800, seed=13)
    set_directory(WorkerDirectory())
    a, b = make_engine("colstore"), make_engine("colstore")
    a.put_block("t", blk)
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t2", workers=2, import_workers=3,
                partition="hash:key", streams=2,
                config=PipeConfig(mode="arrowcol", block_rows=128))
          .compile())
    for _ in range(2):
        b.drop("t2")
        res = cp.execute()
        assert res.single().rows == 800
        np.testing.assert_array_equal(_key_sorted(b.get_block("t2")),
                                      np.arange(800))


def test_chain_through_produced_table_compiles():
    """A table produced by an earlier edge is a valid source (no error),
    and the consumer lands in a later stage."""
    a, b, c = (make_engine("colstore"), make_engine("dataframe"),
               make_engine("rowstore"))
    a.put_block("t", make_paper_block(10))
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t2")
          .move(b, "t2", c, "t3")   # inferred dependency, no .then needed
          .compile())
    assert cp.stages == [["e0"], ["e1"]]
    assert cp.edges[1].depends_on == ("e0",)


# -- explain -------------------------------------------------------------------


def test_explain_decision_snapshot():
    a, b = make_engine("colstore"), make_engine("colstore")
    a.put_block("t", make_paper_block(200, seed=2))
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t2",
                config=PipeConfig(mode="arrowcol", codec="zip"),
                workers=2, import_workers=3)
          .options(partition="hash:key", streams=2, transport="socket")
          .compile())
    d = cp.describe()[0]
    assert d == {
        "edge": "e0",
        "source": "colstore:t",
        "target": "colstore:t2",
        "via": "pipe",
        "mode": "arrowcol",
        "codec": "zip",
        "transport": "socket",
        "workers": 2,
        "import_workers": 3,
        "streams": 2,
        "partition": "hash:key",
        "partition_bounds": None,
        "fanin": 2,
        "negotiated": False,
        "depends_on": [],
        "broadcast": None,
        "retries": 0,
        "resume": True,
    }
    text = cp.explain()
    assert "partition=hash:key" in text and "streams=2" in text
    assert "workers=2->3" in text


def test_explain_reports_range_bounds_before_execution():
    a, b = make_engine("colstore"), make_engine("colstore")
    a.put_block("t", make_paper_block(400, seed=3))
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t2", partition="range:key",
                workers=2, import_workers=4)
          .compile())
    ep = cp.edges[0]
    assert ep.partition_bounds is not None and len(ep.partition_bounds) == 3
    assert "bounds=[" in cp.explain()


def test_negotiated_mode_marked_and_cached():
    from repro.core.plan import _negotiation_cache

    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", make_paper_block(50))
    cp = plan().move(a, "t", b, "t2").compile()
    assert cp.edges[0].negotiated
    assert cp.edges[0].mode == "arrowcol"  # both engines validate the top rung
    assert "colstore" in _negotiation_cache and "dataframe" in _negotiation_cache


# -- back-compat parity --------------------------------------------------------


def test_transfer_shim_matches_one_edge_plan():
    blk = make_paper_block(300, seed=5)
    cfg = PipeConfig(mode="arrowcol", block_rows=64)

    set_directory(WorkerDirectory())
    s1, d1 = make_engine("colstore"), make_engine("dataframe")
    s1.put_block("t", blk)
    r_shim = transfer(s1, "t", d1, "t2", config=cfg, workers=2, timeout=60)

    set_directory(WorkerDirectory())
    s2, d2 = make_engine("colstore"), make_engine("dataframe")
    s2.put_block("t", blk)
    r_plan = (plan(negotiate=False)
              .move(s2, "t", d2, "t2", config=cfg, workers=2, timeout=60)
              .execute().single())

    assert _rows_sorted(d1.get_block("t2")) == _rows_sorted(d2.get_block("t2"))
    assert (r_shim.rows, r_shim.mode, r_shim.codec) == \
        (r_plan.rows, r_plan.mode, r_plan.codec)
    assert r_shim.errors == r_plan.errors == []
    # both paths aggregate real pipe stats through the sink
    for r in (r_shim, r_plan):
        assert r.export_stats is not None and r.export_stats.rows == 300
        assert r.bytes_moved > 0


# -- execution: chains and fan-outs --------------------------------------------


@pytest.mark.parametrize("transport", ["socket", "channel"])
def test_chained_three_engine_plan(transport):
    """A→B→C via the plan API lands bit-identical data vs two sequential
    transfer() calls."""
    blk = make_paper_block(400, seed=6)
    cfg = PipeConfig(mode="arrowcol", block_rows=128, transport=transport)

    set_directory(WorkerDirectory())
    a, b, c = (make_engine("colstore"), make_engine("dataframe"),
               make_engine("colstore"))
    a.put_block("t", blk)
    res = (plan(negotiate=False)
           .move(a, "t", b, "t2", config=cfg)
           .then(b, "t2", c, "t3", config=cfg)
           .execute())
    assert res.ok and res.results["e0"].rows == res.results["e1"].rows == 400

    set_directory(WorkerDirectory())
    a2, b2, c2 = (make_engine("colstore"), make_engine("dataframe"),
                  make_engine("colstore"))
    a2.put_block("t", blk)
    transfer(a2, "t", b2, "t2", config=cfg, timeout=60)
    transfer(b2, "t2", c2, "t3", config=cfg, timeout=60)

    assert _rows_sorted(c.get_block("t3")) == _rows_sorted(c2.get_block("t3"))


@pytest.mark.parametrize("transport", ["socket", "channel"])
def test_fanout_plan_runs_concurrently(transport):
    """A→{B,C}: both edges in one stage, data identical to sequential."""
    blk = make_paper_block(400, seed=7)
    cfg = PipeConfig(mode="arrowcol", block_rows=128, transport=transport)

    set_directory(WorkerDirectory())
    a, b, c = (make_engine("colstore"), make_engine("dataframe"),
               make_engine("rowstore"))
    a.put_block("t", blk)
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t2", config=cfg)
          .move(a, "t", c, "t3", config=cfg)
          .compile())
    assert cp.stages == [["e0", "e1"]]  # independent: one concurrent stage
    res = cp.execute()
    assert res.ok and res.rows == 800

    set_directory(WorkerDirectory())
    a2, b2, c2 = (make_engine("colstore"), make_engine("dataframe"),
                  make_engine("rowstore"))
    a2.put_block("t", blk)
    transfer(a2, "t", b2, "t2", config=cfg, timeout=60)
    transfer(a2, "t", c2, "t3", config=cfg, timeout=60)
    assert _rows_sorted(b.get_block("t2")) == _rows_sorted(b2.get_block("t2"))
    assert _rows_sorted(c.get_block("t3")) == _rows_sorted(c2.get_block("t3"))


# -- broadcast fan-out (one export over a shared shm ring) ---------------------


def test_shm_fanout_compiles_to_single_broadcast_export():
    """A→{B,C,D} over shm: the planner groups the three edges onto ONE
    export feeding one broadcast ring — asserted via explain() and the
    per-edge PipeStats (only the leader carries export stats, with one
    stream's worth of encoded blocks)."""
    blk = make_paper_block(2000, seed=21, strings=True)
    set_directory(WorkerDirectory())
    a = make_engine("colstore")
    dsts = [make_engine("colstore") for _ in range(3)]
    a.put_block("t", blk)
    p = plan(negotiate=False)
    for i, d in enumerate(dsts):
        p.move(a, "t", d, f"t{i}", transport="shm",
               config=PipeConfig(mode="arrowcol", block_rows=256))
    cp = p.compile()
    text = cp.explain()
    assert "broadcast=b0[1-export,3 readers]" in text
    assert text.count("broadcast=b0") == 3
    assert [d["broadcast"] and d["broadcast"]["leader"]
            for d in cp.describe()] == [True, False, False]
    res = cp.execute()
    assert res.ok
    for i, d in enumerate(dsts):
        assert _rows_sorted(d.get_block(f"t{i}")) == _rows_sorted(blk)
    lead = res.edge("e0")
    # exactly one export: one stream of ceil(2000/256) = 8 encoded blocks
    assert lead.export_stats is not None and lead.export_stats.blocks == 8
    assert res.edge("e1").export_stats is None
    assert res.edge("e2").export_stats is None
    # all three importers decoded in-place spans of the ONE ring
    assert lead.import_stats.shm_spans >= 3 * 8


def test_shm_fanout_broadcast_opt_out_runs_independent_exports():
    """broadcast=False keeps the pre-PR behaviour: every edge exports for
    itself (each edge carries its own export stats)."""
    blk = make_paper_block(600, seed=22)
    set_directory(WorkerDirectory())
    a = make_engine("colstore")
    dsts = [make_engine("colstore") for _ in range(2)]
    a.put_block("t", blk)
    p = plan(negotiate=False)
    for i, d in enumerate(dsts):
        p.move(a, "t", d, f"t{i}", transport="shm", broadcast=False,
               config=PipeConfig(mode="arrowcol", block_rows=256))
    cp = p.compile()
    assert all(ep.broadcast_group is None for ep in cp.edges)
    res = cp.execute()
    assert res.ok
    for eid in ("e0", "e1"):
        assert res.edge(eid).export_stats is not None
        assert res.edge(eid).export_stats.blocks == 3  # encoded per edge


def test_mismatched_fanout_edges_not_broadcast_grouped():
    """Edges that disagree on wire framing (block_rows) — or that aren't
    shm at all — stay independent."""
    a = make_engine("colstore")
    b, c, d = (make_engine("colstore"), make_engine("colstore"),
               make_engine("colstore"))
    a.put_block("t", make_paper_block(100, seed=23))
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t1", transport="shm",
                config=PipeConfig(block_rows=128))
          .move(a, "t", c, "t2", transport="shm",
                config=PipeConfig(block_rows=256))
          .move(a, "t", d, "t3", transport="socket",
                config=PipeConfig(block_rows=128))
          .compile())
    assert all(ep.broadcast_group is None for ep in cp.edges)


# -- streams × partition composition -------------------------------------------


@pytest.mark.parametrize("transport", ["socket", "shm"])
def test_striped_shuffle_roundtrip(transport):
    """streams=2 composed with hash partitioning: every shuffle member
    pipe is striped; the relation round-trips losslessly."""
    blk = make_paper_block(2000, seed=8)
    set_directory(WorkerDirectory())
    a, b = make_engine("colstore"), make_engine("colstore")
    a.put_block("t", blk)
    res = (plan(negotiate=False)
           .move(a, "t", b, "t2", workers=2, import_workers=3,
                 partition="hash:key", streams=2, transport=transport,
                 config=PipeConfig(mode="arrowcol", block_rows=128,
                                   shm_capacity=1 << 21))
           .execute())
    r = res.single()
    assert r.rows == 2000 and r.errors == []
    got = b.get_block("t2")
    np.testing.assert_array_equal(_key_sorted(got), np.arange(2000))
    # the striped members really carried frames on both streams
    assert r.export_stats is not None
    streams_seen = {s.get("stream") for s in r.export_stats.per_stream}
    assert streams_seen >= {0, 1}


def test_range_partition_global_bounds_agree_across_exporters():
    """Planner-stamped global bounds: adversarially ordered input (each
    exporter's slice covers a disjoint key range, so per-exporter
    first-block bounds would disagree wildly) still lands every row, and
    each importer receives one contiguous global range."""
    import numpy as np

    from repro.core.types import ColType, ColumnBlock, Field, Schema

    n = 1200
    # exporter 0 sees keys [0,600), exporter 1 sees [600,1200): per-first-
    # block bounds would split each half locally; global bounds must not
    keys = np.arange(n, dtype=np.int64)
    vals = np.arange(n, dtype=np.float64) * 0.5
    blk = ColumnBlock(
        Schema([Field("key", ColType.INT64), Field("v", ColType.FLOAT64)]),
        [keys, vals])
    set_directory(WorkerDirectory())
    a, b = make_engine("colstore"), make_engine("colstore")
    a.put_block("t", blk)
    cp = (plan(negotiate=False)
          .move(a, "t", b, "t2", workers=2, import_workers=3,
                partition="range:key",
                config=PipeConfig(mode="arrowcol", block_rows=64))
          .compile())
    bounds = cp.edges[0].partition_bounds
    assert bounds is not None and len(bounds) == 2
    # bounds are global quantiles of the whole relation
    assert bounds[0] == pytest.approx(np.quantile(keys, 1 / 3))
    res = cp.execute()
    assert res.single().rows == n
    np.testing.assert_array_equal(_key_sorted(b.get_block("t2")), keys)


def test_preset_bounds_row_path_matches_vectorized():
    """With preset bounds the range partitioner places rows identically
    on the scalar (row-serialized) and vectorized (block) paths."""
    blk = make_paper_block(500, seed=9)
    bounds = compute_range_bounds(blk, "key", 4)
    part = parse_partition("range:key", bounds=bounds)
    vec = part.indices(blk, 4)
    scalar = np.array([part.part_of_row(int(k), 4) for k in blk.columns[0]])
    np.testing.assert_array_equal(vec, scalar)


# -- error aggregation ---------------------------------------------------------


class _Boom(Exception):
    pass


def test_transfer_surfaces_both_sides_chained():
    """An import-side failure raises; any export-side failure rides along
    as __context__ instead of being swallowed."""
    blk = make_paper_block(100, seed=10)
    set_directory(WorkerDirectory())
    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", blk)

    def bad_import(*args, **kw):
        raise _Boom("import exploded")

    b.import_csv_parallel = bad_import
    with pytest.raises(_Boom):
        transfer(a, "t", b, "t2", timeout=5,
                 config=PipeConfig(connect_timeout=2.0))


def test_plan_collects_all_edge_errors_and_skips_downstream():
    blk = make_paper_block(100, seed=11)
    set_directory(WorkerDirectory())
    a, b, c = (make_engine("colstore"), make_engine("dataframe"),
               make_engine("rowstore"))
    a.put_block("t", blk)

    def bad_import(*args, **kw):
        raise _Boom("import exploded")

    b.import_csv_parallel = bad_import
    p = (plan(negotiate=False)
         .move(a, "t", b, "t2", timeout=5,
               config=PipeConfig(connect_timeout=2.0))
         .then(b, "t2", c, "t3")
         .move(a, "t", c, "u", config=PipeConfig(block_rows=64)))
    with pytest.raises(PlanExecutionError) as ei:
        p.execute()
    res = ei.value.result
    # the failing edge's import error is recorded, downstream skipped,
    # the independent edge still ran
    assert any(("import" in e and "Boom" in e) for e in res.errors)
    assert "e1" in res.skipped
    assert res.results["e2"].rows == 100
    # the underlying exceptions are chained off the raised error
    assert ei.value.__cause__ is not None
    # partial results remain queryable
    assert res.edge("e2").errors == []


def test_plan_result_errors_populated_on_failed_edge():
    """TransferResult.errors carries every peer failure (not just the
    first), formatted with its side."""
    blk = make_paper_block(100, seed=12)
    set_directory(WorkerDirectory())
    a, b = make_engine("colstore"), make_engine("dataframe")
    a.put_block("t", blk)

    def bad_import(*args, **kw):
        raise _Boom("import exploded")

    b.import_csv_parallel = bad_import
    res = (plan(negotiate=False)
           .move(a, "t", b, "t2", timeout=5,
                 config=PipeConfig(connect_timeout=2.0))
           .execute(raise_on_error=False))
    assert not res.ok
    r = res.results["e0"]
    assert any(e.startswith("import:") for e in r.errors)
