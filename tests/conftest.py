import os
import sys

# tests run on the single real CPU device (the dry-run owns the 512-device
# flag); make jax deterministic and quiet
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.core.directory import WorkerDirectory, set_directory


@pytest.fixture(autouse=True)
def fresh_directory():
    """Each test gets its own worker directory (no cross-test rendezvous)."""
    d = WorkerDirectory()
    set_directory(d)
    yield d
