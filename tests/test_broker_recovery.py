"""Control-plane crash tolerance: the broker journal, fencing epochs,
and the degraded-mode client ladder.

The contract under test (docs/architecture.md, "Control-plane failure
model"): SIGKILL the broker and (a) no committed control-plane state is
lost — the journal replays leases, publications and quota config into
the next incarnation; (b) no *un*committed grant survives — outstanding
admission tickets are expired at recovery and their eventual releases
are fenced off as ``stale_epoch`` instead of double-crediting budgets;
(c) clients never wedge — they walk the degraded ladder (bounded retry
-> process-local fallback rendezvous + no-op admission -> re-attach)
and a 200-plan stress drains green across the kill.
"""

import errno
import multiprocessing
import os
import signal
import socket
import tempfile
import threading
import time

import pytest

from repro.core import faults, telemetry
from repro.core.broker import (
    BrokerClient,
    NullAdmission,
    PipeBroker,
    TenantQuota,
    _fold_records,
    get_broker,
    process_fd_count,
)
from repro.core.datapipe import PipeConfig
from repro.core.directory import (
    DirectoryClient,
    Endpoint,
    get_directory,
)
from repro.core.journal import Journal, JournalError, replay
from repro.core.plan import plan
from repro.core.shm_ring import _SHM_DIR, doorbell_supported
from repro.engines import make_engine, make_paper_block
from repro.engines.base import assert_blocks_equal

_mp = multiprocessing.get_context("spawn")

needs_doorbell = pytest.mark.skipif(
    not doorbell_supported(), reason="platform has no eventfd/fifo doorbell")


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _edge_cfg(**kw):
    kw.setdefault("shm_capacity", 1 << 16)
    return PipeConfig(mode="arrowcol", block_rows=32, transport="shm", **kw)


# -- the journal itself --------------------------------------------------------------


def test_journal_roundtrip(tmp_path):
    path = str(tmp_path / "j")
    j = Journal(path, fsync_batch=2)
    j.append("register", {"dataset": "t", "query_id": "0"})
    j.append("publish_name", {"name": "n", "doc": {"head": 3}})
    j.append("admit", {"ticket": "1.0", "rings": 2})
    j.close()
    records, truncated = replay(path)
    assert not truncated
    assert [k for k, _ in records] == ["register", "publish_name", "admit"]
    assert records[1][1] == {"name": "n", "doc": {"head": 3}}


def test_journal_replay_missing_file_is_empty(tmp_path):
    assert replay(str(tmp_path / "nope")) == ([], False)


def test_journal_replay_tolerates_torn_tail(tmp_path):
    """A crash mid-append tears at most the LAST record: replay drops it,
    keeps everything before it, and flags the truncation."""
    path = str(tmp_path / "j")
    j = Journal(path)
    j.append("register", {"dataset": "a"})
    j.append("register", {"dataset": "b"})
    j.close()
    with open(path, "ab") as fh:  # a torn write: half a record, no CRC
        fh.write(b'deadbeef {"k": "regist')
    records, truncated = replay(path)
    assert truncated
    assert [doc["dataset"] for _, doc in records] == ["a", "b"]


def test_journal_mid_file_corruption_is_loud(tmp_path):
    """Corruption FOLLOWED by intact records cannot be a crash artifact;
    recovering past it would silently drop committed state."""
    path = str(tmp_path / "j")
    j = Journal(path)
    for ds in ("a", "b", "c"):
        j.append("register", {"dataset": ds})
    j.close()
    with open(path, "rb") as fh:
        lines = fh.readlines()
    lines[1] = b"00000000 " + lines[1].split(b" ", 1)[1]  # break the CRC
    with open(path, "wb") as fh:
        fh.writelines(lines)
    with pytest.raises(JournalError):
        replay(path)


def test_journal_checkpoint_is_atomic_and_truncating(tmp_path):
    path = str(tmp_path / "j")
    j = Journal(path, fsync_batch=1)
    for i in range(100):
        j.append("renew", {"dataset": "t", "i": i})
    grew = j.size
    j.checkpoint([("checkpoint", {"state": {"epoch": 7}})])
    assert j.size < grew
    j.append("register", {"dataset": "after"})
    j.close()
    records, truncated = replay(path)
    assert not truncated
    assert [k for k, _ in records] == ["checkpoint", "register"]
    assert records[0][1]["state"]["epoch"] == 7


def test_fold_nets_out_pops_and_releases():
    records = [
        ("register", {"dataset": "t", "query_id": "0", "ep": {"pid": 1}}),
        ("register", {"dataset": "u", "query_id": "0", "ep": {"pid": 2}}),
        ("pop", {"dataset": "t", "query_id": "0", "ep": {"pid": 1}}),
        ("admit", {"ticket": "1.0", "rings": 1}),
        ("admit", {"ticket": "1.1", "rings": 2}),
        ("release", {"ticket": "1.0"}),
        ("publish_name", {"name": "n", "doc": {"head": 1}, "pid": 9}),
        ("publish_name", {"name": "n", "doc": {"head": 5}, "pid": 9}),
    ]
    state = _fold_records(records)
    assert [e["dataset"] for e in state["entries"]] == ["u"]
    assert [e["dataset"] for e in state["popped"]] == ["t"]
    assert set(state["tickets"]) == {"1.1"}  # released grant netted out
    assert state["names"]["n"]["doc"]["head"] == 5  # last write wins


# -- recovery: journal -> next incarnation -------------------------------------------


def test_broker_recovers_leases_names_and_quota(tmp_path):
    path = str(tmp_path / "broker.journal")
    b1 = PipeBroker(journal_path=path, hub=False, lease_ttl=30.0)
    b1.start()
    b1.directory.register("t", Endpoint("h", 1), "q1")
    b1.directory.publish_name("pub", {"head": 12}, lease_s=30.0)
    b1.set_quota("acme", TenantQuota(max_rings=3))
    epoch1 = b1.epoch
    b1.stop()

    b2 = PipeBroker(journal_path=path, hub=False, lease_ttl=30.0)
    b2.start(recover=True)
    try:
        assert b2.epoch > epoch1
        assert b2.directory.epoch == b2.epoch
        # the lease came back (re-stamped fresh), the name at its head
        assert b2.directory.renew("t", "q1", pid=os.getpid()) == 1
        assert b2.directory.lookup_name("pub", timeout=1.0)["head"] == 12
        assert b2.tenants["acme"].max_rings == 3
        assert b2.recovered["entries"] == 1
        assert b2.recovered["names"] == 1
    finally:
        b2.stop()


def test_recovery_treats_popped_endpoints_as_popped(tmp_path):
    """An endpoint handed to an exporter before the crash must not be
    re-offered after it — but its renewals still succeed (the transfer
    is live; renew of a popped entry is not lease loss)."""
    path = str(tmp_path / "broker.journal")
    b1 = PipeBroker(journal_path=path, hub=False)
    b1.start()
    b1.directory.register("t", Endpoint("h", 1, pid=os.getpid()), "q1")
    assert b1.directory.query("t", "q1", timeout=1.0).port == 1
    b1.stop()

    b2 = PipeBroker(journal_path=path, hub=False)
    b2.start(recover=True)
    try:
        assert b2.recovered["entries"] == 0
        assert b2.recovered["popped"] == 1
        assert b2.directory.renew("t", "q1", pid=os.getpid()) == 1
        with pytest.raises(TimeoutError):
            b2.directory.query("t", "q1", timeout=0.1)
    finally:
        b2.stop()


def test_recovery_expires_outstanding_grants(tmp_path):
    """Grants outstanding at the crash do NOT carry their budgets into
    the next incarnation — they are expired, counted, and their rings
    are available again immediately."""
    path = str(tmp_path / "broker.journal")
    b1 = PipeBroker(journal_path=path, hub=False, max_rings=2)
    b1.start()
    b1.admit(rings=2)  # never released: the holder "dies" with b1
    b1.stop()

    b2 = PipeBroker(journal_path=path, hub=False, max_rings=2)
    b2.start(recover=True)
    try:
        assert b2.expired_tickets >= 1
        with b2.admit(rings=2, timeout=1.0):  # budget was not leaked
            pass
    finally:
        b2.stop()


def test_stale_epoch_release_is_fenced():
    """A release of a ticket granted by a dead incarnation must not be
    credited — one crash would otherwise double-spend rings forever."""
    b = PipeBroker(hub=False, max_rings=4)
    b.start()
    adm = b.admit(rings=2)
    b.stop()
    b.start()  # same object, new incarnation: epoch bumped
    try:
        use_before = list(b._use)
        adm.release()  # zombie from the previous epoch
        assert b.stale_releases == 1
        assert list(b._use) == use_before  # nothing un-credited
        adm.release()  # idempotent: second call is a no-op, not a double
        assert b.stale_releases == 1
    finally:
        b.stop()


def test_truncated_tail_recovery_is_counted(tmp_path):
    path = str(tmp_path / "broker.journal")
    b1 = PipeBroker(journal_path=path, hub=False)
    b1.start()
    b1.directory.register("t", Endpoint("h", 1), "q")
    b1.stop()
    with open(path, "ab") as fh:
        fh.write(b"12345678 {torn")  # the crash signature
    before = telemetry.counter("broker.journal_truncated").value
    b2 = PipeBroker(journal_path=path, hub=False)
    b2.start(recover=True)
    try:
        assert telemetry.counter("broker.journal_truncated").value \
            == before + 1
        assert b2.directory.renew("t", "q", pid=os.getpid()) == 1
    finally:
        b2.stop()


# -- lifecycle: restart + install over a stale broker --------------------------------


def test_served_broker_restarts_on_same_port():
    b = PipeBroker(serve=True, hub=False)
    b.start()
    port = b.port
    c = DirectoryClient("127.0.0.1", port)
    epoch_a = c.stats()["epoch"]
    b.stop()
    b.start()
    try:
        assert b.port == port  # clients reconnect where they left off
        st = c.stats()
        assert st["epoch"] == epoch_a + 1
        assert c.epoch == st["epoch"]  # pinned from the response
    finally:
        b.stop()


def test_install_displaces_stale_broker():
    """A crashed scope or leaked fixture can leave a dead broker
    registered process-globally; installing a new one must displace it
    AND survive the stale one's eventual stop()."""
    b1 = PipeBroker(hub=False).install()
    b2 = PipeBroker(hub=False).install()
    try:
        assert get_broker() is b2
        assert get_directory() is b2.directory
        b1.stop()  # the stale broker's cleanup fires late
        assert get_broker() is b2
        assert get_directory() is b2.directory
    finally:
        b2.stop()
        b1.stop()


# -- fencing epochs over the wire ----------------------------------------------------


def test_server_fences_stale_epoch_and_client_adopts():
    b = PipeBroker(serve=True, hub=False)
    b.start()
    try:
        c = DirectoryClient("127.0.0.1", b.port)
        c.stats()
        assert c.epoch == b.epoch
        rejects = telemetry.counter("broker.rejects",
                                    reason="stale_epoch").value
        c.epoch = b.epoch + 5  # a pin from a parallel-universe broker
        st = c.stats()  # rejected once, adopted, replayed
        assert st["epoch"] == b.epoch
        assert c.epoch == b.epoch
        assert telemetry.counter("broker.rejects",
                                 reason="stale_epoch").value > rejects
    finally:
        b.stop()


def test_remote_release_of_dead_incarnations_ticket_is_fenced():
    b = PipeBroker(serve=True, hub=False, max_rings=4)
    b.start()
    port = b.port
    client = BrokerClient("127.0.0.1", port)
    adm = client.admit(rings=2)
    b.stop()
    b.start()  # new incarnation on the same port
    try:
        assert b.port == port
        adm.release()  # ticket "1.x" against epoch 2: fenced, swallowed
        assert b.stale_releases == 1
        assert b._use[0] == 0
    finally:
        b.stop()


def test_broker_restart_fault_rule_drives_epoch_adoption():
    """The seeded ``broker_restart`` rule makes the client see a
    new-incarnation reject without restarting anything for real."""
    b = PipeBroker(serve=True, hub=False)
    b.start()
    try:
        c = DirectoryClient("127.0.0.1", b.port)
        c.stats()
        seen = telemetry.counter("broker.stale_epoch_seen").value
        with faults.FaultPlan().broker_restart(op="stats"):
            c.stats()
        assert telemetry.counter("broker.stale_epoch_seen").value \
            == seen + 1
        assert c.epoch == b.epoch  # settled back on the live incarnation
        assert c.stats()["epoch"] == b.epoch
    finally:
        b.stop()


# -- the degraded-mode ladder --------------------------------------------------------


def test_client_retries_idempotent_rpc_once_on_reset():
    b = PipeBroker(serve=True, hub=False)
    b.start()
    try:
        c = DirectoryClient("127.0.0.1", b.port)
        calls = {"n": 0}
        real = c._rpc_once

        def flaky(req, ack=False):
            if calls["n"] == 0:
                calls["n"] += 1
                raise ConnectionResetError(errno.ECONNRESET,
                                           "broker restarted mid-RPC")
            return real(req, ack)

        c._rpc_once = flaky
        assert "epoch" in c.stats()  # retried: recovery, not an error
        assert calls["n"] == 1

        calls["n"] = 0
        c.register("t", Endpoint("h", 1), "q")  # register is an upsert
        assert calls["n"] == 1
        assert b.directory.renew("t", "q", pid=os.getpid()) == 1

        # a non-retryable op surfaces the error instead (query pops)
        def always(req, ack=False):
            raise ConnectionResetError(errno.ECONNRESET, "down")

        c._rpc_once = always
        with pytest.raises(OSError):
            c.query("t", "q", timeout=0.1)
    finally:
        b.stop()


def test_dead_broker_degrades_to_local_rendezvous():
    port = _free_port()  # nobody listening: every connect is refused
    c = DirectoryClient("127.0.0.1", port, degraded_ok=True,
                        probe_every=3600.0)
    c.register("t", Endpoint("h", 7, pid=os.getpid()), "q")
    assert c.degraded
    assert telemetry.gauge("broker.degraded").value == 1
    # the fallback serves the whole rendezvous surface in-process
    assert c.query("t", "q", timeout=1.0).port == 7
    assert c.renew("t", "q", lease_s=5.0) == 1
    c.publish_name("n", {"head": 1})
    assert c.lookup_name("n", timeout=1.0)["head"] == 1
    # a lease the dead broker holds is SUSPENDED, not lost: renew says 1
    assert c.renew("elsewhere", "q9") == 1
    assert c.renew_name("elsewhere") == 1


def test_degraded_client_reattaches_and_reuploads_names():
    b = PipeBroker(serve=True, hub=False)
    b.start()
    try:
        c = DirectoryClient("127.0.0.1", b.port, degraded_ok=True,
                            probe_every=0.05)
        with faults.FaultPlan().broker_crash(op="publish_name"):
            c.publish_name("pub", {"head": 4})  # the broker "dies" here
        assert c.degraded
        time.sleep(0.06)  # past the probe interval
        st = c.stats()  # the probe lands: re-attach
        assert not c.degraded
        assert c.reattaches == 1
        assert st["epoch"] == b.epoch
        # the name published while degraded is visible at the broker now
        assert b.directory.lookup_name("pub", timeout=1.0)["head"] == 4
    finally:
        b.stop()


def test_degraded_admission_is_noop_and_counted():
    port = _free_port()
    client = BrokerClient("127.0.0.1", port, degraded_ok=True)
    before = telemetry.counter("broker.admit_degraded").value
    adm = client.admit(rings=8)
    assert isinstance(adm, NullAdmission)
    assert adm.degraded
    adm.release()
    adm.release()  # idempotent no-op
    assert telemetry.counter("broker.admit_degraded").value == before + 1


def test_admission_release_is_idempotent_under_threads():
    b = PipeBroker(hub=False, max_rings=2)
    b.start()
    try:
        adm = b.admit(rings=2)
        threads = [threading.Thread(target=adm.release) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(5.0)
        assert b._use == [0, 0, 0]  # released exactly once
        with b.admit(rings=2, timeout=1.0):
            pass
    finally:
        b.stop()


# -- the acceptance bar: SIGKILL mid-stress ------------------------------------------


def _serve_broker(port: int, journal: str, recover: bool) -> None:
    """Child process: a served broker that lives until SIGKILLed."""
    b = PipeBroker(serve=True, host="127.0.0.1", port=port, hub=False,
                   journal_path=journal, max_rings=16, lease_ttl=10.0,
                   sweep_every=1.0, admit_timeout=120.0)
    b.start(recover=recover)
    while True:
        time.sleep(3600.0)


def _wait_for_port(port: int, timeout: float = 15.0) -> None:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            socket.create_connection(("127.0.0.1", port), timeout=1.0).close()
            return
        except OSError:
            time.sleep(0.05)
    raise TimeoutError(f"broker child never listened on {port}")


def _orphan_snapshot():
    shm = {n for n in os.listdir(_SHM_DIR) if n.startswith("pgring-")} \
        if os.path.isdir(_SHM_DIR) else set()
    fifos = {n for n in os.listdir(tempfile.gettempdir()) if ".pgdb-" in n}
    return shm, fifos


@needs_doorbell
def test_sigkill_broker_mid_stress_drains_green(tmp_path):
    """SIGKILL the broker under a 200-plan stress, restart it from the
    journal on the same port: every plan drains bit-identical, the new
    incarnation fences the old epoch's zombies, and nothing leaks."""
    n_plans = 200
    journal = str(tmp_path / "broker.journal")
    port = _free_port()
    shm_before, fifo_before = _orphan_snapshot()
    child = _mp.Process(target=_serve_broker, args=(port, journal, False),
                        daemon=True)
    child.start()
    _wait_for_port(port)

    client = BrokerClient("127.0.0.1", port, admit_timeout=120.0)
    client.directory.probe_every = 0.2
    client.install()
    child2 = None
    try:
        src, dst = make_engine("colstore"), make_engine("colstore")
        blocks = {}
        for i in range(n_plans):
            blocks[i] = make_paper_block(32, seed=i)
            src.put_block(f"t{i}", blocks[i])
        base_fds = process_fd_count()
        failures = []
        started = threading.Semaphore(0)

        def one(i):
            started.release()
            try:
                res = (plan(negotiate=False)
                       .move(src, f"t{i}", dst, f"d{i}",
                             config=_edge_cfg(), timeout=10)
                       .options(retries=3, backoff=0.1)
                       .compile()
                       .execute())
                assert res.ok, res.errors
            except Exception as e:  # noqa: BLE001 - aggregated below
                failures.append((i, repr(e)))

        threads = [threading.Thread(target=one, args=(i,))
                   for i in range(n_plans)]
        for t in threads:
            t.start()
        for _ in range(n_plans):
            started.acquire()
        time.sleep(0.4)  # mid-stress: grants out, queue deep, plans live

        os.kill(child.pid, signal.SIGKILL)
        child.join(10.0)
        time.sleep(0.3)
        child2 = _mp.Process(target=_serve_broker,
                             args=(port, journal, True), daemon=True)
        child2.start()
        _wait_for_port(port)

        for t in threads:
            t.join(timeout=300.0)
        assert not any(t.is_alive() for t in threads)
        assert not failures, failures[:5]
        for i in range(n_plans):
            assert_blocks_equal(blocks[i], dst.get_block(f"d{i}"),
                                check_names=False)

        # give stragglers (stale releases, re-attach probes) a beat
        deadline = time.monotonic() + 10.0
        stale = 0
        while time.monotonic() < deadline:
            st = client.stats()
            counters = st["metrics"]["counters"]
            stale = (counters.get("broker.rejects{reason=stale_epoch}", 0)
                     + st.get("stale_releases", 0))
            if stale and st["epoch"] == 2 and not client.degraded:
                break
            time.sleep(0.2)
        assert st["epoch"] == 2  # recovered incarnation, fenced
        assert stale > 0  # old-epoch zombies were rejected, not credited
        assert not client.degraded  # the ladder stepped back up
        assert client.directory.reattaches >= 1
    finally:
        client.stop()
        for p in (child, child2):
            if p is not None and p.is_alive():
                p.terminate()
                p.join(5.0)

    # abandoned attempt sides (exporter died at rendezvous) time out on
    # their own connect_timeout and release their rings — wait them out
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline and any(
            t.name.startswith(("pipegen-import", "pipegen-export"))
            for t in threading.enumerate()):
        time.sleep(0.2)
    from repro.core.shm_ring import drain_pools
    drain_pools()
    shm_after, fifo_after = _orphan_snapshot()
    assert not (shm_after - shm_before)  # no orphaned rings
    assert not (fifo_after - fifo_before)  # no orphaned doorbells
    # fds from just-reaped straggler threads close asynchronously —
    # give the count a moment to settle before calling it a leak
    deadline = time.monotonic() + 15.0
    after_fds = process_fd_count()
    while after_fds > base_fds + 8 and time.monotonic() < deadline:
        time.sleep(0.25)
        after_fds = process_fd_count()
    assert after_fds <= base_fds + 8, (base_fds, after_fds)
