"""AString (section 5.1): string-protocol fidelity + typed-part recovery."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.astring import AString, materialize_part


def test_concat_keeps_parts():
    s = AString.of(1) + AString.literal(",") + AString.of("a")
    assert list(s.parts) == [1, ",", "a"]
    assert str(s) == "1,a"


def test_paper_example_internal_state():
    # fig. 8(c): accumulated values after one loop iteration
    s = AString.of(1) + AString.literal(",") + AString.of(2.5)
    assert s.parts[0] == 1 and s.parts[1] == "," and s.parts[2] == 2.5


def test_parse_skips_materialization():
    assert AString.parse_int(AString.of(42)) == 42
    assert AString.parse_float(AString.of(2.5)) == 2.5
    assert AString.parse_bool(AString.of(True)) is True


def test_parse_from_characters():
    assert AString.parse_int(AString(("17",))) == 17
    assert AString.parse_float(AString(("-2.5",))) == -2.5


def test_split_on_delimiter_typed():
    s = AString((1, ",", 2.5, ",", "x"))
    cells = s.split(",")
    assert [c.sole_value for c in cells] == [1, 2.5, "x"]


def test_split_character_fallback():
    s = AString(("1,2,3",))
    cells = s.split(",")
    assert [str(c) for c in cells] == ["1", "2", "3"]


def test_float_text_roundtrip_exact():
    # repr-based rendering must round-trip doubles exactly (the paper's
    # 24-byte float example)
    v = -2.2250738585072020e-308
    assert float(materialize_part(v)) == v


@given(st.lists(st.one_of(
    st.integers(-2**63, 2**63 - 1),
    st.floats(allow_nan=False, allow_infinity=False),
    st.text(alphabet=st.characters(blacklist_characters=",\n\r"),
            max_size=8),
), min_size=1, max_size=10))
@settings(max_examples=60, deadline=None)
def test_materialization_matches_plain_strings(vals):
    """Property: AString renders exactly like plain-str concatenation."""
    plain = ",".join(
        ("true" if v else "false") if isinstance(v, bool)
        else (repr(v) if isinstance(v, float) else str(v))
        for v in vals)
    parts = []
    for i, v in enumerate(vals):
        if i:
            parts.append(",")
        parts.append(v)
    assert str(AString(parts)) == plain


@given(st.lists(st.integers(-10**9, 10**9), min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_split_recovers_values(ints):
    parts = []
    for i, v in enumerate(ints):
        if i:
            parts.append(",")
        parts.append(v)
    cells = AString(parts).split(",")
    assert [AString.parse_int(c) for c in cells] == list(ints)
