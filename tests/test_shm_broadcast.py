"""Broadcast shm ring (one writer, R reader cursor slots): bit-identical
1→3 delivery in and across processes, slow-reader backpressure via
min-tail recycling, reader-SIGKILL eviction that must not wedge the
writer, and the directory's join/publish broadcast rendezvous."""

import multiprocessing
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.datapipe import DataPipeInput, DataPipeOutput, PipeConfig
from repro.core.directory import (
    DirectoryClient,
    DirectoryServer,
    Endpoint,
    WorkerDirectory,
    set_directory,
)
from repro.core.shm_ring import ShmRing, ShmRingTransport
from repro.core.transport import FRAME_EOF, FRAME_TEXT
from repro.core.types import ColumnBlock
from repro.engines.base import assert_blocks_equal, make_paper_block

_mp = multiprocessing.get_context("spawn")

JOIN_S = 60


def _join_or_kill(procs):
    deadline = time.monotonic() + JOIN_S
    for p in procs:
        p.join(max(0.1, deadline - time.monotonic()))
    hung = [p for p in procs if p.is_alive()]
    for p in hung:
        p.kill()
        p.join(5)
    assert not hung, "child process hung (broadcast ring must fail fast)"


# -- directory rendezvous -----------------------------------------------------------


def test_join_broadcast_hands_out_slots_and_blocks_on_publication():
    d = WorkerDirectory()
    got = {}

    def join_late(i):
        got[i] = d.join_broadcast("ds", "q", readers=3, timeout=10.0)

    t1 = threading.Thread(target=join_late, args=(1,), daemon=True)
    t2 = threading.Thread(target=join_late, args=(2,), daemon=True)
    slot, ep = d.join_broadcast("ds", "q", readers=3)
    assert (slot, ep) == (0, None)  # first joiner creates the ring
    t1.start()
    t2.start()
    time.sleep(0.1)
    assert not got  # later joiners block until publication
    d.publish_broadcast("ds", Endpoint(shm_name="seg", shm_capacity=64,
                                       broadcast=3, shared=True), "q",
                        import_workers=1)
    t1.join(JOIN_S)
    t2.join(JOIN_S)
    slots = sorted(s for s, _ in got.values())
    assert slots == [1, 2]
    assert all(e.shm_name == "seg" and e.broadcast == 3
               for _, e in got.values())
    # the publication doubles as the exporter-facing registration
    assert d.query("ds", "q", export_workers=1).shm_name == "seg"


def test_join_broadcast_rejects_mismatch_and_exhaustion():
    d = WorkerDirectory()
    slot, ep = d.join_broadcast("ds", "q", readers=2)
    assert (slot, ep) == (0, None)
    with pytest.raises(IOError, match="disagree"):
        d.join_broadcast("ds", "q", readers=3)
    d.publish_broadcast("ds", Endpoint(shm_name="seg", shm_capacity=64,
                                       broadcast=2, shared=True), "q")
    slot, ep = d.join_broadcast("ds", "q", readers=2)
    assert slot == 1 and ep.shm_name == "seg"
    with pytest.raises(IOError, match="already claimed"):
        d.join_broadcast("ds", "q", readers=2)


# -- in-process delivery ------------------------------------------------------------


def test_broadcast_1x3_bit_identical_in_process():
    set_directory(WorkerDirectory())
    name = "db://bcast-inproc?query=1"
    block = make_paper_block(5000, seed=7, strings=True)
    got = {}

    def imp(i):
        pipe = DataPipeInput(name, transport="shm", broadcast=3,
                             shm_capacity=1 << 20)
        got[i] = list(pipe.blocks())
        pipe.close()
        got[f"stats{i}"] = pipe.stats

    ts = [threading.Thread(target=imp, args=(i,), daemon=True)
          for i in range(3)]
    for t in ts:
        t.start()
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol",
                                                 block_rows=512))
    out.write_block(block)
    out.close()
    for t in ts:
        t.join(JOIN_S)
    assert not any(t.is_alive() for t in ts)
    for i in range(3):
        assert_blocks_equal(block, ColumnBlock.concat(got[i]),
                            check_names=False)
        assert got[f"stats{i}"].shm_spans > 0  # decoded in place
    # the writer encoded ONE stream: schema + ceil(5000/512) blocks + EOF
    assert out.stats.blocks == 10
    assert out.stats.frames_sent == 12


def test_broadcast_slow_reader_applies_backpressure():
    """Recycling is gated on min(tails): a lagging reader stalls the
    writer (bounded memory), and draining it releases everything to
    everyone."""
    ring = ShmRing.create(capacity=4096, role="reader", readers=2)
    fast = ShmRingTransport(ring)  # creator holds slot 0
    slow_ring = ShmRing.attach(ring.name, role="reader", slot=1)
    slow = ShmRingTransport(slow_ring)
    tx = ShmRingTransport(ShmRing.attach(ring.name, role="writer"))
    n_frames, payload = 16, b"x" * 1000
    sent = []

    def send():
        for i in range(n_frames):
            tx.send_frames(FRAME_TEXT, [payload])
            sent.append(i)

    th = threading.Thread(target=send, daemon=True)
    th.start()
    for _ in range(3):  # the fast reader takes what already fits
        kind, p = fast.recv_frame()
        assert (kind, bytes(p)) == (FRAME_TEXT, payload)
    time.sleep(0.3)
    # at most ~4 frames fit in 4096 bytes and slot 1 has consumed none:
    # the writer must be blocked on the slow cursor, not overwriting
    assert th.is_alive() and len(sent) < n_frames
    got = {0: 3, 1: 0}

    def drain(rx, idx, want):
        for _ in range(want):
            kind, p = rx.recv_frame()
            assert (kind, bytes(p)) == (FRAME_TEXT, payload)
            got[idx] += 1

    d0 = threading.Thread(target=drain, args=(fast, 0, n_frames - 3),
                          daemon=True)
    d1 = threading.Thread(target=drain, args=(slow, 1, n_frames),
                          daemon=True)
    d0.start()
    d1.start()
    th.join(JOIN_S)
    d0.join(JOIN_S)
    d1.join(JOIN_S)
    assert len(sent) == n_frames
    assert got == {0: n_frames, 1: n_frames}  # every frame, both readers
    tx.close()
    slow_ring.close()
    ring.close()


# -- cross-process children ---------------------------------------------------------


def _child_bcast_importer(dir_addr, name, q, idx):
    set_directory(DirectoryClient(*dir_addr))
    pipe = DataPipeInput(name, transport="shm", broadcast=3,
                         shm_capacity=1 << 20)
    rows = 0
    key_sum = 0
    for block in pipe.blocks():
        rows += len(block)
        key_sum += int(np.asarray(block.columns[0]).sum())
    pipe.close()
    q.put((idx, rows, key_sum, pipe.stats.shm_spans))


def _child_bcast_exporter(dir_addr, name, n_rows, q):
    set_directory(DirectoryClient(*dir_addr))
    out = DataPipeOutput(name, config=PipeConfig(mode="arrowcol",
                                                 block_rows=512))
    out.write_block(make_paper_block(n_rows, seed=11))
    out.close()
    q.put(("exp", out.stats.blocks, out.stats.frames_sent))


def test_broadcast_1x3_across_processes():
    """Three importer processes and one exporter process share ONE ring
    through the DirectoryServer's join/publish rendezvous; the exporter
    encodes each block exactly once."""
    n_rows = 8000
    server = DirectoryServer().start()
    try:
        q = _mp.Queue()
        name = "db://bcast-xproc?query=b1"
        addr = (server.host, server.port)
        procs = [
            _mp.Process(target=_child_bcast_importer,
                        args=(addr, name, q, i))
            for i in range(3)
        ]
        procs.append(_mp.Process(target=_child_bcast_exporter,
                                 args=(addr, name, n_rows, q)))
        for p in procs:
            p.start()
        # 2x margin: four simultaneous spawns each pay interpreter+import
        # startup, which stacks up on a loaded CI box
        results = [q.get(timeout=2 * JOIN_S) for _ in range(4)]
        _join_or_kill(procs)
        exp = next(r for r in results if r[0] == "exp")
        imps = [r for r in results if r[0] != "exp"]
        assert len(imps) == 3
        want_sum = n_rows * (n_rows - 1) // 2
        for _, rows, key_sum, spans in imps:
            assert rows == n_rows
            assert key_sum == want_sum  # bit-identical key column
            assert spans > 0
        # one export: ceil(8000/512) = 16 blocks, sent once, not thrice
        assert exp[1] == 16
    finally:
        server.stop()


def _child_bcast_reader_then_die(name, slot, frames_before_death, attached):
    ring = ShmRing.attach(name, role="reader", slot=slot)
    rx = ShmRingTransport(ring)
    attached.set()
    for _ in range(frames_before_death):
        rx.recv_frame()
    os.kill(os.getpid(), signal.SIGKILL)  # no close, slot left attached


def test_broadcast_reader_sigkill_is_evicted_not_wedging_writer():
    """A SIGKILLed reader's cursor stops moving; the writer must evict it
    by pid-probe once blocked and keep feeding the survivors."""
    ring = ShmRing.create(capacity=8192, role="reader", readers=2)
    attached = _mp.Event()
    p = _mp.Process(target=_child_bcast_reader_then_die,
                    args=(ring.name, 1, 2, attached))
    p.start()
    assert attached.wait(JOIN_S)
    tx = ShmRingTransport(ShmRing.attach(ring.name, role="writer"),
                          send_timeout=30.0)
    rx = ShmRingTransport(ring)
    n_frames, payload = 64, b"y" * 1024  # far beyond one ring's worth
    recvd = []

    def drain():
        for _ in range(n_frames):
            kind, pl = rx.recv_frame()
            recvd.append(bytes(pl))

    td = threading.Thread(target=drain, daemon=True)
    td.start()
    for _ in range(n_frames):  # must neither hang nor raise
        tx.send_frames(FRAME_TEXT, [payload])
    td.join(JOIN_S)
    assert not td.is_alive()
    assert recvd == [payload] * n_frames  # the survivor got everything
    assert tx.ring.readers_evicted >= 1
    _join_or_kill([p])
    tx.close()
    rx.close()


def _child_bcast_writer_then_die(name):
    w = ShmRingTransport(ShmRing.attach(name, role="writer"))
    for i in range(3):
        w.send_frames(FRAME_TEXT, [b"frame-%d" % i])
    os.kill(os.getpid(), signal.SIGKILL)


def test_broadcast_ring_pools_and_reuses_warm_segments():
    """A cleanly drained broadcast group parks its segment; the next
    group of the same shape re-leases it warm (slot table re-reserved,
    lease epoch bumped) and still delivers only its own data."""
    from repro.core.shm_ring import acquire_broadcast_ring

    cap = 20480  # capacity no other test parks

    def one_group(payloads):
        ring = acquire_broadcast_ring(cap, readers=2)
        r1 = ShmRing.attach(ring.name, role="reader", slot=1)
        tx = ShmRingTransport(ShmRing.attach(ring.name, role="writer"))
        rx0, rx1 = ShmRingTransport(ring), ShmRingTransport(r1)
        for p in payloads:
            tx.send_frames(FRAME_TEXT, [p])
        tx.send_frames(FRAME_EOF, [b""])
        got = {0: [], 1: []}
        for idx, rx in ((1, rx1), (0, rx0)):  # peer drains+closes first,
            while True:                       # so the owner's park lands
                kind, p = rx.recv_frame()
                if kind == FRAME_EOF:
                    break
                got[idx].append(bytes(p))
            rx.close()
        tx.close()
        assert got[0] == got[1] == payloads
        return ring

    r_a = one_group([b"group-a-%d" % i for i in range(4)])
    r_b = one_group([b"group-b-%d" % i for i in range(6)])
    assert r_b is r_a  # warm reuse of the parked segment
    assert r_b._epoch != 0  # fresh lease epoch: stale words cannot match
    # drain the pool so later tests see a clean slate
    r_c = acquire_broadcast_ring(cap, readers=2)
    assert r_c is r_a
    r_c.reader_close()


def test_broadcast_reserved_slot_evicted_after_claim_grace(monkeypatch):
    """An importer that dies between the directory join and the ring
    attach leaves its slot RESERVED; once the claim grace expires the
    writer evicts it instead of wedging, and a too-late attach fails
    loudly (its frames are already recycled)."""
    import repro.core.shm_ring as sr

    monkeypatch.setattr(sr, "_RESERVED_GRACE", 0.3)
    ring = ShmRing.create(capacity=4096, role="reader", readers=2)
    tx = ShmRingTransport(ShmRing.attach(ring.name, role="writer"),
                          send_timeout=30.0)
    rx = ShmRingTransport(ring)
    n_frames, payload = 32, b"x" * 1000  # far beyond one ring's worth
    got = []

    def drain():
        for _ in range(n_frames):
            kind, p = rx.recv_frame()
            got.append(bytes(p))

    td = threading.Thread(target=drain, daemon=True)
    td.start()
    for _ in range(n_frames):  # blocks on the reserved slot, then evicts
        tx.send_frames(FRAME_TEXT, [payload])
    td.join(JOIN_S)
    assert got == [payload] * n_frames
    assert tx.ring.readers_evicted >= 1
    with pytest.raises(IOError, match="evicted"):
        ShmRing.attach(ring.name, role="reader", slot=1)
    tx.close()
    rx.close()


def test_broadcast_writer_death_drains_then_eof():
    """Writer dies uncleanly: every reader drains what was published and
    then sees end-of-stream (same contract as the SPSC ring)."""
    ring = ShmRing.create(capacity=8192, role="reader", readers=2)
    r1 = ShmRing.attach(ring.name, role="reader", slot=1)

    p = _mp.Process(target=_child_bcast_writer_then_die, args=(ring.name,))
    p.start()
    for rx in (ShmRingTransport(ring), ShmRingTransport(r1)):
        got = []
        while True:
            kind, payload = rx.recv_frame()
            if kind == FRAME_EOF:
                break
            got.append(bytes(payload))
        assert got == [b"frame-0", b"frame-1", b"frame-2"]
    _join_or_kill([p])
    r1.close()
    ring.close()


def test_broadcast_warm_park_survives_straggler_readers():
    """The owner closing first must not forfeit warm reuse: a group whose
    peers finish far apart (well past the old ~20 ms inline probe) hands
    the segment to the background parker, which pools it once the last
    straggler drains — instead of unlinking and paying first-touch faults
    on the next group."""
    import repro.core.shm_ring as sr

    cap = 24576  # capacity no other test parks
    ring = sr.acquire_broadcast_ring(cap, readers=2)
    name = ring.name
    r1 = ShmRing.attach(name, role="reader", slot=1)
    tx = ShmRingTransport(ShmRing.attach(name, role="writer"))
    rx0, rx1 = ShmRingTransport(ring), ShmRingTransport(r1)
    for i in range(3):
        tx.send_frames(FRAME_TEXT, [b"warm-%d" % i])
    tx.send_frames(FRAME_EOF, [b""])
    tx.close()
    # the OWNER (slot 0) drains and closes first, straggler still attached
    while rx0.recv_frame()[0] != FRAME_EOF:
        pass
    rx0.close()  # must hand off to the background parker, not unlink

    def parked() -> bool:
        with sr._park_lock:
            return any(r.name == name
                       for lst in sr._bc_parked.values() for r in lst)

    time.sleep(0.1)  # well past the old inline probe window
    assert not parked()  # straggler still live: segment not pooled yet
    got = []
    while True:  # the straggler can still read: segment was not unlinked
        kind, p = rx1.recv_frame()
        if kind == FRAME_EOF:
            break
        got.append(bytes(p))
    assert got == [b"warm-0", b"warm-1", b"warm-2"]
    rx1.close()
    deadline = time.monotonic() + 3 * sr._BC_PARK_WAIT
    while not parked() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert parked()  # background parker pooled it after the stragglers left
    r2 = sr.acquire_broadcast_ring(cap, readers=2)
    assert r2.name == name  # warm reuse
    assert r2._epoch != 0  # fresh lease epoch
    r2.reader_close()  # drain the pool so later tests see a clean slate
