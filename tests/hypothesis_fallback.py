"""Graceful degradation when `hypothesis` is not installed.

The property-based tests are a bonus layer over the deterministic suite;
on boxes without hypothesis the whole module used to fail at collection,
taking every deterministic test in the file down with it.  Import sites use

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypothesis_fallback import given, settings, st

so property tests turn into explicit skips while everything else runs.
"""

import pytest


class _Strategy:
    """Inert stand-in: strategy construction happens at decoration time, so
    attribute access and chained calls must all succeed."""

    def __call__(self, *a, **k):
        return self

    def __getattr__(self, name):
        return self

    def map(self, fn):
        return self

    def filter(self, fn):
        return self


st = _Strategy()


def given(*args, **kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return deco


def settings(*args, **kwargs):
    def deco(fn):
        return fn

    return deco
