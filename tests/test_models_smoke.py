"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, output shapes + no NaNs (the assignment's required smoke surface)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCHS, build_model, get_config
from repro.models import encdec
from repro.train.optimizer import adamw_init, adamw_update

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(RNG, (B, S, cfg.d_model)),
            "positions": jnp.zeros((3, B, S), jnp.int32)
            + jnp.arange(S)[None, None, :],
            "labels": jnp.zeros((B, S), jnp.int32),
        }
    if cfg.is_encdec:
        return {
            "frames": jax.random.normal(RNG, (B, S, cfg.d_model)),
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32),
        }
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    B, S = 2, 16

    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    # one full train step (grad + adamw) must keep everything finite
    def loss_of(p):
        return model.loss_fn(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    new_params, opt, metrics = adamw_update(params, grads, opt,
                                            jnp.asarray(1e-3))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 16
    if cfg.is_encdec:
        cache = model.init_cache(B, 32, enc_len=S)
        enc = encdec.encode(params, cfg, jax.random.normal(RNG, (B, S, cfg.d_model)))
        cache = model.precompute_cross(params, enc, cache)
        dbatch = {"token": jnp.zeros((B, 1), jnp.int32)}
    elif cfg.family == "vlm":
        cache = model.init_cache(B, 32)
        dbatch = {"embed": jax.random.normal(RNG, (B, 1, cfg.d_model))}
    else:
        cache = model.init_cache(B, 32)
        dbatch = {"token": jnp.zeros((B, 1), jnp.int32)}
    step = jax.jit(lambda p, c, b: model.decode_step(p, c, b))
    lg, cache = step(params, cache, dbatch)
    lg2, cache = step(params, cache, dbatch)
    assert lg.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(lg2).any())
    assert int(cache["index"]) == 2


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce full-forward logits (dense)."""
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks,
                                  "labels": jnp.zeros((B, S), jnp.int32)})
    cache = model.init_cache(B, S)
    step = jax.jit(lambda p, c, b: model.decode_step(p, c, b))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, {"token": toks[:, t:t + 1]})
        outs.append(np.asarray(lg[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(full, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_rwkv():
    """Stateful decode equals the scan-over-time forward (rwkv6)."""
    cfg = get_config("rwkv6-3b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks,
                                  "labels": jnp.zeros((B, S), jnp.int32)})
    cache = model.init_cache(B, S)
    step = jax.jit(lambda p, c, b: model.decode_step(p, c, b))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, {"token": toks[:, t:t + 1]})
        outs.append(np.asarray(lg[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(full, np.float32),
                               rtol=5e-4, atol=5e-4)


def test_param_counts_are_plausible():
    for name, cfg in ARCHS.items():
        n = cfg.param_count()
        assert n > 1e8, (name, n)
        if cfg.moe_experts:
            assert cfg.active_param_count() < n
